//! End-to-end serving driver (the repo's full-stack proof): load the real
//! AOT-compiled models (python/jax/pallas → HLO text → PJRT), start the
//! live multi-worker coordinator, serve a batched Poisson request stream
//! through the four Figure-1 pipelines, and report latency/throughput.
//!
//! All three layers compose here: L1 pallas kernels inside the L2 jax
//! models (baked into the HLO artifacts), executed by the L3 rust
//! coordinator with Compass scheduling. Python is not running.
//!
//!     make artifacts   # once
//!     cargo run --release --example serve_pipelines

use compass::coordinator::{LiveCluster, LiveConfig};
use compass::runtime::{artifacts_dir, Runtime};
use compass::util::stats::percentile;
use compass::{ClusterConfig, PipelineKind, SchedulerKind};
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();

    // 1. Verify the artifacts exist and handshake python-vs-rust numerics.
    let rt = Runtime::load(&dir)?;
    println!("loaded + handshaken {} PJRT model executables from {}:", rt.len(), dir.display());
    for name in rt.names() {
        let m = rt.get(name).unwrap();
        println!("  {:10} id={} [{}x{}]", name, m.meta.model_id, m.meta.seq_len, m.meta.d_model);
    }
    drop(rt); // workers each load their own client below

    // 2. Serve 60 requests at 2 req/s through the live coordinator
    //    (time-scale 50: profiled seconds replay at 50x).
    let cfg = ClusterConfig::default().with_scheduler(SchedulerKind::Compass).with_seed(11);
    let live = LiveConfig { time_scale: 50.0, wall_timeout: Duration::from_secs(300) };
    let jobs = compass::workload::poisson(2.0, 60, &[], 23);

    println!("\nserving {} requests at 2 req/s on 5 live workers...", jobs.len());
    let t0 = Instant::now();
    let report = LiveCluster::run(cfg, live, Some(dir), jobs)?;
    let wall = t0.elapsed();

    let m = &report.metrics;
    let lats: Vec<f64> = m.jobs.iter().map(|j| j.latency_us() as f64 / 1e6).collect();
    println!("\nresults ({} jobs, wall {:.1} s):", m.jobs.len(), wall.as_secs_f64());
    println!(
        "  latency (profiled time): p50 {:.2} s  p95 {:.2} s  max {:.2} s",
        percentile(&lats, 50.0),
        percentile(&lats, 95.0),
        percentile(&lats, 100.0)
    );
    println!("  mean slow-down          : {:.2}x", m.mean_slowdown());
    println!(
        "  throughput              : {:.2} jobs/s (profiled time)",
        m.jobs.len() as f64 / (m.span_us as f64 / 1e6)
    );
    println!("  GPU cache hit rate      : {:.1}%", m.cache_hit_rate());
    println!(
        "  real PJRT executions    : {} (mean {} µs each)",
        report.pjrt_executions, report.mean_pjrt_exec_us
    );

    for kind in PipelineKind::ALL {
        let s = m.slowdowns_of(kind);
        if !s.is_empty() {
            println!(
                "  {:14} n={:3}  median slow-down {:.2}x",
                kind.name(),
                s.len(),
                percentile(&s, 50.0)
            );
        }
    }
    Ok(())
}
