//! Ablation playground (the Figure 7 scenario as an application): toggle
//! each Compass feature from the command line and see the impact.
//!
//!     cargo run --release --example ablation -- --rate 2.5 \
//!         [--no-dynamic-adjust] [--fifo] [--no-locality] [--threshold 2.0]

use compass::gpu::EvictionPolicy;
use compass::util::args::Args;
use compass::{ClusterConfig, SchedulerKind, Simulator};

fn main() {
    let args = Args::from_env();
    let rate = args.get_f64("rate", 2.5);
    let jobs = compass::workload::poisson(rate, args.get_usize("jobs", 400), &[], 13);

    let mut cfg = ClusterConfig::default().with_scheduler(SchedulerKind::Compass).with_seed(13);
    if args.flag("no-dynamic-adjust") {
        cfg.compass.dynamic_adjust = false;
    }
    if args.flag("no-locality") {
        cfg.compass.model_locality = false;
    }
    if args.flag("fifo") {
        cfg.eviction = EvictionPolicy::Fifo;
    }
    cfg.compass.adjust_threshold = args.get_f64("threshold", cfg.compass.adjust_threshold);

    println!(
        "compass variant: dynamic_adjust={} model_locality={} eviction={:?} threshold={}",
        cfg.compass.dynamic_adjust, cfg.compass.model_locality, cfg.eviction,
        cfg.compass.adjust_threshold
    );

    let base = Simulator::simulate(
        ClusterConfig::default().with_scheduler(SchedulerKind::Compass).with_seed(13),
        jobs.clone(),
    )
    .metrics;
    let variant = Simulator::simulate(cfg, jobs).metrics;

    println!("\n{:>22}  {:>10}  {:>10}", "", "full", "variant");
    println!(
        "{:>22}  {:>10.2}  {:>10.2}",
        "mean slow-down", base.mean_slowdown(), variant.mean_slowdown()
    );
    println!(
        "{:>22}  {:>9.1}%  {:>9.1}%",
        "cache hit rate", base.cache_hit_rate(), variant.cache_hit_rate()
    );
    println!(
        "{:>22}  {:>10.2}  {:>10.2}",
        "mean latency (s)", base.mean_latency_s(), variant.mean_latency_s()
    );
}
