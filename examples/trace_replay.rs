//! Production-trace replay (the Figure 9 scenario as an application):
//! synthesize an Alibaba-like bursty trace, replay it under all four
//! schedulers, and print per-burst completion behaviour.
//!
//!     cargo run --release --example trace_replay [-- --duration 300]

use compass::util::args::Args;
use compass::util::table;
use compass::{ClusterConfig, SchedulerKind, Simulator};

fn main() {
    let args = Args::from_env();
    let duration = args.get_f64("duration", 300.0);
    let (jobs, buckets) = compass::workload::alibaba_like(2.0, duration, 99);

    println!("synthesized trace: {} jobs over {:.0} s", jobs.len(), duration);
    println!("arrival-rate timeline (req/s per 5 s bucket):");
    let spark: String = buckets
        .iter()
        .map(|b| {
            let levels = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
            let peak = buckets.iter().map(|x| x.rate_per_s).fold(0.0, f64::max);
            levels[((b.rate_per_s / peak * 9.0) as usize).min(9)]
        })
        .collect();
    println!("  [{spark}]");

    let mut rows = Vec::new();
    for s in SchedulerKind::ALL {
        let cfg = ClusterConfig::default().with_scheduler(s).with_seed(5);
        let m = Simulator::simulate(cfg, jobs.clone()).metrics;
        let lats: Vec<f64> = m.jobs.iter().map(|j| j.latency_us() as f64 / 1e6).collect();
        rows.push(vec![
            s.name().to_string(),
            format!("{:.2}", compass::util::stats::percentile(&lats, 50.0)),
            format!("{:.2}", compass::util::stats::percentile(&lats, 95.0)),
            format!("{:.2}", compass::util::stats::percentile(&lats, 100.0)),
            format!("{:.2}", m.mean_slowdown()),
            format!("{:.1}", m.cache_hit_rate()),
        ]);
    }
    print!(
        "\n{}",
        table::render(
            &["scheduler", "p50 (s)", "p95 (s)", "max (s)", "mean slowdown", "hit rate %"],
            &rows
        )
    );
    println!("\n(expected shape: hash degrades the most through bursts; compass stays lowest)");
}
