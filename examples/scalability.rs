//! Scalability demo (the Figure 10 scenario as an application): sweep the
//! cluster size at a fixed aggregate request rate and show how many workers
//! each scheduler actually needs.
//!
//!     cargo run --release --example scalability -- [--rate 40] [--jobs 1500]

use compass::util::args::Args;
use compass::util::table;
use compass::{ClusterConfig, SchedulerKind, Simulator};

fn main() {
    let args = Args::from_env();
    let rate = args.get_f64("rate", 40.0);
    let n_jobs = args.get_usize("jobs", 1500);
    let jobs = compass::workload::poisson(rate, n_jobs, &[], 21);

    let sizes = [25usize, 50, 75, 100, 150];
    let mut rows = Vec::new();
    for &w in &sizes {
        let mut cells = vec![w.to_string()];
        for s in [SchedulerKind::Compass, SchedulerKind::Hash] {
            let cfg = ClusterConfig::default().with_scheduler(s).with_workers(w).with_seed(21);
            let m = Simulator::simulate(cfg, jobs.clone()).metrics;
            cells.push(format!("{:.2}", m.median_slowdown()));
            cells.push(m.active_workers().to_string());
        }
        rows.push(cells);
    }
    println!("{rate} req/s mixed workload, {n_jobs} jobs:");
    print!(
        "{}",
        table::render(
            &["workers", "compass slowdown", "compass active", "hash slowdown", "hash active"],
            &rows
        )
    );
    println!("\nidle workers under compass can be powered down — the paper's Fig. 10 claim.");
}
