//! Define a custom workflow in the `.dfg` text format and schedule it —
//! the "bring your own pipeline" path a downstream user would take.
//!
//!     cargo run --release --example custom_workflow

use compass::dfg::parse::parse_dfg;
use compass::dfg::PipelineKind;
use compass::net::CostModel;
use compass::sched::{self, ClusterView};
use compass::sst::SstRow;
use compass::ClusterConfig;

const DOC: &str = "\
pipeline av-perception
task ingress   runtime_ms=10  output_kb=300
task objects   model=detr runtime_ms=300 output_kb=50
task depth     model=glpn-depth runtime_ms=350 output_kb=1000
task captions  model=vit-gpt2 runtime_ms=250 output_kb=2
task fuse      runtime_ms=40 output_kb=120
edge ingress -> objects
edge ingress -> depth
edge ingress -> captions
edge objects -> fuse
edge depth -> fuse
edge captions -> fuse
";

fn main() -> anyhow::Result<()> {
    let cost = CostModel::default();
    let dfg = parse_dfg(DOC, PipelineKind::Perception, &cost)?;

    println!("parsed workflow '{}' with {} tasks:", "av-perception", dfg.len());
    for v in &dfg.vertices {
        println!(
            "  [{}] {:10} model={:?} runtime={} ms rank={:.0} ms",
            v.id,
            v.name,
            v.model,
            v.mean_runtime_us / 1000,
            dfg.ranks[v.id] / 1000.0
        );
    }
    println!(
        "lower bound (max parallelism, all cached): {:.2} s",
        dfg.lower_bound_us as f64 / 1e6
    );

    // Plan it with the Compass scheduler on a 5-worker view.
    let cfg = ClusterConfig::default();
    let scheduler = sched::build(&cfg);
    let rows = vec![SstRow { free_cache_bytes: cfg.gpu_capacity, ..Default::default() }; 5];
    let speed = vec![1.0; 5];
    let view = ClusterView { now: 0, self_worker: 0, rows: &rows, cost: &cost, speed: &speed };
    let job = compass::Job {
        id: 1,
        kind: PipelineKind::Perception,
        arrival_us: 0,
        input_bytes: 300_000,
    };
    let adfg = scheduler.plan(&job, &dfg, &view);
    println!("\nplanned ADFG (task -> worker):");
    for (t, w) in adfg.assignment.iter().enumerate() {
        println!("  {:10} -> worker {}", dfg.vertices[t].name, w.unwrap());
    }
    // The three parallel branches should spread across workers.
    let branch_workers: std::collections::HashSet<_> =
        [1, 2, 3].iter().map(|&t| adfg.get(t).unwrap()).collect();
    println!("\nparallel branches use {} distinct workers", branch_workers.len());
    Ok(())
}
