//! Quickstart: build a cluster, generate a small mixed workload, run the
//! Compass scheduler in the simulator, and print the headline metrics.
//!
//!     cargo run --release --example quickstart

use compass::{ClusterConfig, SchedulerKind, Simulator};

fn main() {
    // The paper's testbed: 5 workers, 16 GB GPU cache each.
    let cfg = ClusterConfig::default()
        .with_scheduler(SchedulerKind::Compass)
        .with_workers(5)
        .with_seed(42);

    // 200 requests at 2 req/s over the four Figure-1 pipelines.
    let jobs = compass::workload::poisson(2.0, 200, &[], 7);

    let report = Simulator::simulate(cfg, jobs);
    let m = &report.metrics;

    println!("Compass quickstart — 200 jobs at 2 req/s on 5 workers");
    println!("  completed jobs      : {}", m.jobs.len());
    println!("  mean latency        : {:.2} s", m.mean_latency_s());
    println!("  mean slow-down      : {:.2}x of the theoretical lower bound", m.mean_slowdown());
    println!("  GPU cache hit rate  : {:.1}%", m.cache_hit_rate());
    println!("  GPU utilization     : {:.0}%", m.gpu_utilization());
    println!("  energy              : {:.0} J", m.gpu_energy_joules());

    // Compare against the Hash load balancer on the identical workload.
    let hash_cfg = ClusterConfig::default().with_scheduler(SchedulerKind::Hash).with_seed(42);
    let hash = Simulator::simulate(hash_cfg, compass::workload::poisson(2.0, 200, &[], 7));
    println!(
        "\n  vs hash load-balancing: {:.2}x mean slow-down ({:.1}x worse than compass)",
        hash.metrics.mean_slowdown(),
        hash.metrics.mean_slowdown() / m.mean_slowdown()
    );
}
