//! Integration: scheduler-level behaviours the paper calls out, exercised
//! through the public API (experiment harness included).

use compass::config::{ClusterConfig, SchedulerKind};
use compass::dfg::PipelineKind;
use compass::exp::{self, Scale};
use compass::{workload, Simulator};

fn quick() -> Scale {
    Scale { jobs: 120, seed: 42 }
}

#[test]
fn fig6_rate_sweep_monotone_for_everyone() {
    // More load can't make anyone faster (statistically).
    let r = exp::fig6::rate_sweep(quick());
    for s in SchedulerKind::ALL {
        let lo = r.mean(s, 0);
        let hi = r.mean(s, r.rates.len() - 1);
        assert!(hi > lo * 0.9, "{s:?}: hi {hi} vs lo {lo}");
    }
}

#[test]
fn fig6_compass_wins_high_load_boxes() {
    let b = exp::fig6::boxes(2.0, quick(), "test");
    let c = b.median_overall(SchedulerKind::Compass);
    for s in [SchedulerKind::Heft, SchedulerKind::Hash] {
        assert!(b.median_overall(s) > c, "{s:?} not worse than compass");
    }
}

#[test]
fn fig6_short_pipelines_suffer_most_under_bad_scheduling() {
    // §6.2.2: the short pipelines' slowdown blows up worst for HEFT.
    let b = exp::fig6::boxes(2.0, quick(), "test");
    let heft_perception = b.stats(SchedulerKind::Heft, PipelineKind::Perception).median;
    let heft_vpa = b.stats(SchedulerKind::Heft, PipelineKind::Vpa).median;
    assert!(
        heft_perception > heft_vpa,
        "perception {heft_perception} !> vpa {heft_vpa}"
    );
}

#[test]
fn table1_shape_matches_paper() {
    let rows = exp::table1::compute(quick());
    let get = |s: SchedulerKind| rows.iter().find(|r| r.scheduler == s).unwrap();
    let compass = get(SchedulerKind::Compass);
    // Latency: compass lowest.
    for s in [SchedulerKind::Jit, SchedulerKind::Heft, SchedulerKind::Hash] {
        assert!(get(s).latency_s > compass.latency_s, "{s:?}");
    }
    // Hit rate: compass highest, high in absolute terms (>85% even at
    // quick scale where cold-start misses weigh more; 95%+ at full scale).
    assert!(compass.hit_rate_pct > 85.0, "{}", compass.hit_rate_pct);
    // Resource parity: GPU utilization within a few points of each other.
    let utils: Vec<f64> = rows.iter().map(|r| r.gpu_util_pct).collect();
    let spread = utils.iter().cloned().fold(0.0, f64::max)
        - utils.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(spread < 25.0, "GPU util spread too wide: {utils:?}");
}

#[test]
fn fig7_every_ablation_hurts_at_high_load() {
    let rows = exp::fig7::compute(quick());
    let full = rows.iter().find(|r| r.variant == "compass-full").unwrap();
    let hi = exp::fig7::RATES.len() - 1;
    for r in &rows {
        if r.variant == "compass-full" {
            continue;
        }
        assert!(
            r.means[hi] > full.means[hi] * 0.95,
            "{}: {} vs full {}",
            r.variant,
            r.means[hi],
            full.means[hi]
        );
    }
    // Model locality is the biggest lever (paper: 8x, hit rate 99->90).
    let noloc = rows.iter().find(|r| r.variant == "no-model-locality").unwrap();
    assert!(noloc.means[hi] > full.means[hi] * 1.1);
    assert!(noloc.hit_rate_pct < full.hit_rate_pct);
}

#[test]
fn fig8_load_axis_dominates() {
    let g = exp::fig8::compute(quick());
    assert!(
        g.load_axis_sensitivity() > g.cache_axis_sensitivity(),
        "load {} !> cache {}",
        g.load_axis_sensitivity(),
        g.cache_axis_sensitivity()
    );
}

#[test]
fn fig9_compass_best_through_bursts() {
    let r = exp::fig9::compute(quick());
    let get = |s: SchedulerKind| r.rows.iter().find(|x| x.scheduler == s).unwrap();
    let compass = get(SchedulerKind::Compass);
    assert!(get(SchedulerKind::Hash).p95_s > compass.p95_s);
    assert!(get(SchedulerKind::Heft).p95_s > compass.p95_s);
}

#[test]
fn fig10_compass_more_resource_efficient_than_hash() {
    let r = exp::fig10::compute(Scale { jobs: 120, seed: 42 }, true);
    // At every cluster size, compass concentrates: active workers <= hash's.
    for (c, h) in r.compass.iter().zip(&r.hash) {
        assert!(
            c.active_workers <= h.active_workers,
            "at {} workers: compass active {} > hash active {}",
            c.workers,
            c.active_workers,
            h.active_workers
        );
    }
    // Hash always keeps (almost) everyone busy.
    let last = r.hash.last().unwrap();
    assert!(last.active_workers as f64 > 0.9 * last.workers as f64);
}

#[test]
fn identical_streams_across_schedulers() {
    // The comparison methodology requires every scheduler to see the exact
    // same request stream.
    let a = workload::poisson(2.0, 50, &[], 42);
    let b = workload::poisson(2.0, 50, &[], 42);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.arrival_us, y.arrival_us);
        assert_eq!(x.kind, y.kind);
        assert_eq!(x.input_bytes, y.input_bytes);
    }
}

#[test]
fn seeds_change_outcomes_but_not_shape() {
    let mut compass_wins = 0;
    for seed in [1u64, 2, 3] {
        let jobs = workload::poisson(2.0, 150, &[], seed);
        let c = Simulator::simulate(
            ClusterConfig::default().with_seed(seed),
            jobs.clone(),
        )
        .metrics
        .mean_slowdown();
        let h = Simulator::simulate(
            ClusterConfig::default().with_scheduler(SchedulerKind::Hash).with_seed(seed),
            jobs,
        )
        .metrics
        .mean_slowdown();
        if c < h {
            compass_wins += 1;
        }
    }
    assert!(compass_wins >= 2, "compass won only {compass_wins}/3 seeds");
}
