//! Integration: PJRT runtime against the real AOT artifacts.
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! trivially with a notice) when artifacts/ is absent so `cargo test`
//! works on a fresh checkout.

use compass::dfg::models::MODELS;
use compass::runtime::{artifacts_dir, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime load"))
}

#[test]
fn loads_all_eight_models_with_handshakes() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.len(), 8);
    for m in &MODELS {
        assert!(rt.get(m.artifact).is_some(), "artifact {} missing", m.artifact);
        assert!(rt.get_by_id(m.id).is_some(), "model id {} missing", m.id);
    }
}

#[test]
fn execute_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let m = rt.get("espnet").unwrap();
    let x = m.smoke_input();
    let a = m.execute(&x).unwrap();
    let b = m.execute(&x).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.len(), m.meta.seq_len * m.meta.d_model);
}

#[test]
fn execute_rejects_bad_shape() {
    let Some(rt) = runtime() else { return };
    let m = rt.get("espnet").unwrap();
    assert!(m.execute(&[0.0; 7]).is_err());
}

#[test]
fn outputs_are_finite_and_nontrivial() {
    let Some(rt) = runtime() else { return };
    for name in rt.names() {
        let m = rt.get(name).unwrap();
        let y = m.execute(&m.smoke_input()).unwrap();
        assert!(y.iter().all(|v| v.is_finite()), "{name} produced non-finite output");
        let abssum: f32 = y.iter().map(|v| v.abs()).sum();
        assert!(abssum > 0.1, "{name} output suspiciously near zero");
    }
}

#[test]
fn distinct_models_compute_distinct_functions() {
    let Some(rt) = runtime() else { return };
    // espnet and glpn share [16, 32] shapes but have different weights and
    // depths: outputs on the same input must differ.
    let a = rt.get("espnet").unwrap();
    let b = rt.get("glpn").unwrap();
    assert_eq!(
        (a.meta.seq_len, a.meta.d_model),
        (b.meta.seq_len, b.meta.d_model),
        "test assumes shared activation shape"
    );
    let x = a.smoke_input();
    let ya = a.execute(&x).unwrap();
    let yb = b.execute(&x).unwrap();
    assert_ne!(ya, yb);
}

#[test]
fn manifest_metadata_consistent_with_model_table() {
    let Some(rt) = runtime() else { return };
    for m in &MODELS {
        let cm = rt.get(m.artifact).unwrap();
        assert_eq!(cm.meta.model_id, m.id, "{}", m.artifact);
    }
}
