//! Property tests on the fault-injection invariants (DESIGN.md §9): a
//! disabled fault config leaves the simulator bit-identical to the
//! failure-free build (the subsystem must be *inert*, not just quiet),
//! crashed-worker runs retire every task of every completed job exactly
//! once (recovery re-executes orphans, never double-retires), and a
//! seeded chaos run is deterministic end to end.

use std::collections::HashMap;

use compass::config::{ClusterConfig, SchedulerKind};
use compass::core::{Micros, MS, SEC};
use compass::dfg::pipelines;
use compass::metrics::FaultStats;
use compass::net::CostModel;
use compass::obs::TraceEvent;
use compass::util::prop::check;
use compass::{workload, Simulator};

/// Inert fault knobs — any setting that does not *enable* injection
/// (heartbeat threshold, retry policy, fault seed, slowdown shape with a
/// zero rate) — must leave every observable bit-identical to the default
/// config. This is the empty-plan ⇒ byte-identical acceptance gate.
#[test]
fn prop_inert_fault_config_is_bit_identical() {
    check("fault-off-identity", 31, |rng| {
        let n_jobs = 10 + rng.below(30) as usize;
        let rate = 0.5 + rng.f64() * 4.0;
        let kind = SchedulerKind::ALL[rng.below(4) as usize];
        let n_workers = 2 + rng.below(8) as usize;
        let seed = rng.next_u64();
        let jobs = workload::poisson(rate, n_jobs, &[], seed ^ 1);

        let base = ClusterConfig::default()
            .with_scheduler(kind)
            .with_workers(n_workers)
            .with_seed(seed);
        let mut knobs = base.clone();
        // Every rate stays zero; everything else is fair game.
        knobs.fault.heartbeat_timeout_us = 100 * MS + rng.below(10 * SEC);
        knobs.fault.retry.max_attempts = 1 + rng.below(6) as u32;
        knobs.fault.retry.backoff_base_us = 1 + rng.below(SEC);
        knobs.fault.seed = rng.next_u64();
        knobs.fault.slowdown_factor = 1.0 + rng.f64() * 9.0;
        knobs.fault.slowdown_us = rng.below(10 * SEC);
        knobs.fault.crash_window_us = 1 + rng.below(30 * SEC);

        let a = Simulator::simulate(base, jobs.clone());
        let b = Simulator::simulate(knobs, jobs);
        if a.events_processed != b.events_processed {
            return Err(format!(
                "event counts diverged: {} vs {}",
                a.events_processed, b.events_processed
            ));
        }
        if a.sim_span_us != b.sim_span_us {
            return Err("sim span diverged".into());
        }
        let la: Vec<Micros> = a.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        let lb: Vec<Micros> = b.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        if la != lb {
            return Err("per-job latencies diverged".into());
        }
        if a.metrics.mean_latency_s().to_bits() != b.metrics.mean_latency_s().to_bits()
            || a.metrics.mean_slowdown().to_bits() != b.metrics.mean_slowdown().to_bits()
        {
            return Err("f64 aggregates not bit-identical".into());
        }
        if b.metrics.faults != FaultStats::default() {
            return Err(format!("inert config reported fault activity: {:?}", b.metrics.faults));
        }
        Ok(())
    });
}

/// Crashed-worker runs: every job reaches a terminal record, and every
/// job that *completed* (cleanly or degraded) executed each of its tasks
/// exactly once — recovery re-places orphans but never double-retires.
#[test]
fn prop_crash_runs_retire_each_task_exactly_once() {
    check("crash-exactly-once", 32, |rng| {
        let n_workers = 3 + rng.below(6) as usize;
        let n_jobs = 15 + rng.below(30) as usize;
        let seed = rng.next_u64();
        let mut cfg = ClusterConfig::default().with_workers(n_workers).with_seed(seed);
        cfg.trace.enabled = true;
        cfg.fault.crash_rate = 0.2 + rng.f64() * 0.6;
        cfg.fault.seed = rng.next_u64();
        if rng.below(2) == 1 {
            // Mix in an explicit early crash so recovery always triggers.
            let w = rng.below(n_workers as u64) as usize;
            cfg.fault.crashes = vec![(w, 1 + rng.below(5 * SEC))];
        }
        let jobs = workload::poisson(2.0, n_jobs, &[], seed ^ 1);
        let rep = Simulator::simulate(cfg, jobs);

        if rep.metrics.jobs.len() != n_jobs || rep.metrics.incomplete != 0 {
            return Err(format!(
                "{} records + {} incomplete for {n_jobs} jobs: not terminal",
                rep.metrics.jobs.len(),
                rep.metrics.incomplete
            ));
        }
        if rep.trace.dropped != 0 {
            return Err("trace ring overflowed; invariants unverifiable".into());
        }

        let cost = CostModel::default();
        let mut kind_of = HashMap::new();
        for ev in &rep.trace.events {
            if let TraceEvent::JobArrive { job, kind, .. } = *ev {
                kind_of.insert(job, kind);
            }
        }
        let mut ends: HashMap<(u64, u16), usize> = HashMap::new();
        for ev in &rep.trace.events {
            if let TraceEvent::ExecEnd { job, task, .. } = *ev {
                *ends.entry((job, task)).or_default() += 1;
            }
        }
        for (&(job, task), &n) in &ends {
            if n != 1 {
                return Err(format!("task {task} of job {job} retired {n} times"));
            }
        }
        // Completed (incl. degraded) jobs executed their whole pipeline.
        for ev in &rep.trace.events {
            if let TraceEvent::JobComplete { job, .. } = *ev {
                let kind = kind_of[&job];
                let n_tasks = pipelines::by_kind(kind, &cost).len();
                for task in 0..n_tasks {
                    if !ends.contains_key(&(job, task as u16)) {
                        return Err(format!(
                            "job {job} completed but task {task} never retired"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// A seeded chaos run — crashes, slowdowns, fetch failures, fabric faults
/// all at once — is deterministic: two identical invocations agree on
/// every record and every fault counter.
#[test]
fn prop_chaos_runs_are_deterministic() {
    check("chaos-determinism", 33, |rng| {
        let seed = rng.next_u64();
        let mk = |seed: u64, fault_seed: u64| {
            let mut cfg = ClusterConfig::default().with_seed(seed);
            cfg.fault.crash_rate = 0.3;
            cfg.fault.slowdown_rate = 0.3;
            cfg.fault.fetch_fail_prob = 0.2;
            cfg.fault.drop_prob = 0.1;
            cfg.fault.delay_prob = 0.2;
            cfg.fault.seed = fault_seed;
            let jobs = workload::poisson(2.0, 25, &[], seed ^ 1);
            Simulator::simulate(cfg, jobs)
        };
        let fault_seed = rng.next_u64();
        let a = mk(seed, fault_seed);
        let b = mk(seed, fault_seed);
        if a.events_processed != b.events_processed {
            return Err("event counts diverged across identical runs".into());
        }
        if a.metrics.faults != b.metrics.faults {
            return Err(format!(
                "fault stats diverged: {:?} vs {:?}",
                a.metrics.faults, b.metrics.faults
            ));
        }
        let la: Vec<(Micros, bool)> =
            a.metrics.jobs.iter().map(|j| (j.completion_us, j.failed())).collect();
        let lb: Vec<(Micros, bool)> =
            b.metrics.jobs.iter().map(|j| (j.completion_us, j.failed())).collect();
        if la != lb {
            return Err("job records diverged across identical runs".into());
        }
        Ok(())
    });
}
