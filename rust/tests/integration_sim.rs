//! Integration: end-to-end simulator behaviour across modules (dfg +
//! sched + gpu + sst + workload + metrics).

use compass::config::{ClusterConfig, SchedulerKind};
use compass::core::SEC;
use compass::dfg::{Job, PipelineKind};
use compass::gpu::EvictionPolicy;
use compass::{workload, Simulator};

#[test]
fn full_mixed_workload_all_complete() {
    let jobs = workload::poisson(2.0, 300, &[], 42);
    let rep = Simulator::simulate(ClusterConfig::default(), jobs);
    assert_eq!(rep.metrics.jobs.len(), 300);
    assert_eq!(rep.metrics.incomplete, 0);
    // Every kind was exercised.
    for kind in PipelineKind::ALL {
        assert!(!rep.metrics.slowdowns_of(kind).is_empty(), "{kind:?}");
    }
}

#[test]
fn compass_beats_baselines_at_high_load() {
    // The paper's core claim (Fig. 6b): at 2 req/s Compass has the lowest
    // latency of the four schedulers on an identical workload.
    let jobs = workload::poisson(2.0, 400, &[], 7);
    let mut means = std::collections::HashMap::new();
    for s in SchedulerKind::ALL {
        let cfg = ClusterConfig::default().with_scheduler(s);
        let m = Simulator::simulate(cfg, jobs.clone()).metrics;
        means.insert(s, m.mean_slowdown());
    }
    let compass = means[&SchedulerKind::Compass];
    // JIT is the strong baseline; the paper's margin over it is the
    // smallest, so allow a statistical tie (±5%) on any single seed.
    assert!(compass < means[&SchedulerKind::Jit] * 1.05, "{means:?}");
    assert!(compass < means[&SchedulerKind::Heft], "{means:?}");
    assert!(compass < means[&SchedulerKind::Hash], "{means:?}");
    // HEFT (no load awareness) should be the worst, by a clear margin.
    assert!(means[&SchedulerKind::Heft] > 1.5 * compass, "{means:?}");
}

#[test]
fn compass_has_best_cache_hit_rate() {
    let jobs = workload::poisson(2.0, 300, &[], 17);
    let mut hits = std::collections::HashMap::new();
    for s in SchedulerKind::ALL {
        let cfg = ClusterConfig::default().with_scheduler(s);
        let m = Simulator::simulate(cfg, jobs.clone()).metrics;
        hits.insert(s, m.cache_hit_rate());
    }
    let compass = hits[&SchedulerKind::Compass];
    assert!(compass > 90.0, "compass hit rate {compass}");
    for s in [SchedulerKind::Heft, SchedulerKind::Hash] {
        assert!(compass > hits[&s], "{hits:?}");
    }
}

#[test]
fn low_load_everyone_near_optimal() {
    // Fig. 6a: at 0.5 req/s all schedulers are close to slowdown 1.
    let jobs = workload::poisson(0.5, 200, &[], 3);
    for s in SchedulerKind::ALL {
        let cfg = ClusterConfig::default().with_scheduler(s);
        let m = Simulator::simulate(cfg, jobs.clone()).metrics;
        assert!(m.median_slowdown() < 3.5, "{s:?}: {}", m.median_slowdown());
    }
}

#[test]
fn lookahead_eviction_not_worse_than_fifo_under_load() {
    let jobs = workload::poisson(2.5, 300, &[], 23);
    let la = Simulator::simulate(ClusterConfig::default(), jobs.clone()).metrics;
    let mut cfg = ClusterConfig::default();
    cfg.eviction = EvictionPolicy::Fifo;
    let fifo = Simulator::simulate(cfg, jobs).metrics;
    assert!(
        la.mean_slowdown() <= fifo.mean_slowdown() * 1.05,
        "lookahead {} vs fifo {}",
        la.mean_slowdown(),
        fifo.mean_slowdown()
    );
}

#[test]
fn staleness_hurts_at_load() {
    // Fig. 8 x-axis: second-scale load staleness must cost performance vs
    // 100 ms staleness under pressure.
    let jobs = workload::poisson(2.5, 300, &[], 31);
    let mut fresh_cfg = ClusterConfig::default();
    fresh_cfg.push.load_interval_us = 100_000;
    let mut stale_cfg = ClusterConfig::default();
    stale_cfg.push.load_interval_us = 2_000_000;
    let fresh = Simulator::simulate(fresh_cfg, jobs.clone()).metrics;
    let stale = Simulator::simulate(stale_cfg, jobs).metrics;
    assert!(
        stale.mean_slowdown() > fresh.mean_slowdown(),
        "stale {} !> fresh {}",
        stale.mean_slowdown(),
        fresh.mean_slowdown()
    );
}

#[test]
fn back_to_back_same_pipeline_exploits_cache() {
    // A burst of identical pipelines should see high hit rates after warmup.
    let jobs: Vec<Job> = (0..30)
        .map(|i| Job {
            id: i,
            kind: PipelineKind::Vpa,
            arrival_us: i * SEC,
            input_bytes: 500,
        })
        .collect();
    let m = Simulator::simulate(ClusterConfig::default(), jobs).metrics;
    assert!(m.cache_hit_rate() > 90.0, "hit rate {}", m.cache_hit_rate());
}

#[test]
fn bigger_cluster_reduces_slowdown_under_pressure() {
    let jobs = workload::poisson(4.0, 400, &[], 11);
    let small = Simulator::simulate(ClusterConfig::default().with_workers(3), jobs.clone());
    let big = Simulator::simulate(ClusterConfig::default().with_workers(10), jobs);
    assert!(
        big.metrics.mean_slowdown() < small.metrics.mean_slowdown(),
        "big {} !< small {}",
        big.metrics.mean_slowdown(),
        small.metrics.mean_slowdown()
    );
}

#[test]
fn heterogeneous_workers_prefer_fast_ones() {
    // Worker 0 is 4x slower than the rest: compass should push most work
    // off it.
    let jobs = workload::poisson(2.0, 200, &[], 19);
    let mut cfg = ClusterConfig::default();
    cfg.worker_speed = vec![4.0, 1.0, 1.0, 1.0, 1.0]; // speed factor = runtime multiplier
    let m = Simulator::simulate(cfg, jobs).metrics;
    let busy: Vec<u64> = m.workers.iter().map(|w| w.busy_us).collect();
    let slow = busy[0];
    let fast_mean: u64 = busy[1..].iter().sum::<u64>() / 4;
    assert!(slow < fast_mean, "slow worker busier: {busy:?}");
}

#[test]
fn trace_replay_completes_under_all_schedulers() {
    let (jobs, _) = workload::alibaba_like(2.0, 120.0, 5);
    let n = jobs.len();
    for s in SchedulerKind::ALL {
        let cfg = ClusterConfig::default().with_scheduler(s);
        let m = Simulator::simulate(cfg, jobs.clone()).metrics;
        assert_eq!(m.jobs.len(), n, "{s:?}");
    }
}

#[test]
fn online_profiles_converge_and_do_no_harm_when_misprofiled() {
    // Deployment where tasks actually take 3x the profiled runtimes
    // (paper §3.2: actual runtimes are unpredictable). A *uniform* bias
    // barely shifts relative placement decisions (all FT comparisons scale
    // together), so the guarantee to test is: (a) the online Workflow
    // Profiles Repository converges to the true runtimes, and (b) the
    // refinement never harms scheduling quality.
    let jobs = workload::poisson(0.8, 300, &[], 47);
    let mut static_cfg = ClusterConfig::default();
    static_cfg.runtime_bias = 3.0;
    let mut online_cfg = static_cfg.clone();
    online_cfg.profile_alpha = 0.3;
    let frozen = Simulator::simulate(static_cfg, jobs.clone()).metrics;
    let online = Simulator::simulate(online_cfg, jobs).metrics;
    assert_eq!(online.jobs.len(), 300);
    assert!(
        online.mean_slowdown() < frozen.mean_slowdown() * 1.10,
        "online {} vs frozen {}",
        online.mean_slowdown(),
        frozen.mean_slowdown()
    );
    // Convergence check through the ProfileRepository directly.
    use compass::dfg::pipelines;
    use compass::net::CostModel;
    use compass::profiles::ProfileRepository;
    use compass::util::rng::Rng;
    let dfgs = pipelines::all(&CostModel::default());
    let mut repo = ProfileRepository::from_dfgs(&dfgs, 0.3);
    let mut rng = Rng::new(1);
    for _ in 0..300 {
        for kind in PipelineKind::ALL {
            for v in &dfgs[kind.index()].vertices {
                let actual = rng.jitter(v.mean_runtime_us as f64 * 3.0, 0.1, 1.0);
                repo.observe(kind, v.id, actual as u64);
            }
        }
    }
    let err = repo.mean_rel_error(&|k: PipelineKind, t| {
        dfgs[k.index()].vertices[t].mean_runtime_us * 3
    });
    assert!(err < 0.05, "profiles failed to converge: rel err {err}");
}

#[test]
fn accurate_profiles_unaffected_by_online_refinement() {
    // With bias 1.0 the refinement should be ~neutral (estimates already
    // correct), not harmful.
    let jobs = workload::poisson(2.0, 200, &[], 53);
    let mut online_cfg = ClusterConfig::default();
    online_cfg.profile_alpha = 0.3;
    let frozen = Simulator::simulate(ClusterConfig::default(), jobs.clone()).metrics;
    let online = Simulator::simulate(online_cfg, jobs).metrics;
    assert!(
        online.mean_slowdown() < frozen.mean_slowdown() * 1.15,
        "online {} vs frozen {}",
        online.mean_slowdown(),
        frozen.mean_slowdown()
    );
}

#[test]
fn straggler_injection_degrades_latency() {
    // Sanity: injected stragglers must actually hurt.
    let jobs = workload::poisson(1.5, 250, &[], 61);
    let clean = Simulator::simulate(ClusterConfig::default(), jobs.clone()).metrics;
    let mut faulty_cfg = ClusterConfig::default();
    faulty_cfg.straggler_prob = 0.10;
    faulty_cfg.straggler_factor = 5.0;
    let faulty = Simulator::simulate(faulty_cfg, jobs).metrics;
    assert_eq!(faulty.jobs.len(), 250);
    assert!(
        faulty.mean_slowdown() > clean.mean_slowdown(),
        "stragglers had no effect: {} vs {}",
        faulty.mean_slowdown(),
        clean.mean_slowdown()
    );
}

#[test]
fn dynamic_adjustment_absorbs_stragglers_better_than_locked_plans() {
    // The §3.2 motivation for the two-phase design: when actual runtimes
    // blow through their profiles, Compass's dynamic adjustment re-places
    // queued tasks around the straggler, while plan-locked HEFT ships
    // everything to workers whose queues are now stuck.
    let jobs = workload::poisson(1.5, 300, &[], 71);
    let run = |s: SchedulerKind| {
        let mut cfg = ClusterConfig::default().with_scheduler(s);
        cfg.straggler_prob = 0.10;
        cfg.straggler_factor = 5.0;
        Simulator::simulate(cfg, jobs.clone()).metrics.mean_slowdown()
    };
    let compass = run(SchedulerKind::Compass);
    let heft = run(SchedulerKind::Heft);
    assert!(
        compass * 1.5 < heft,
        "compass {compass} should absorb stragglers far better than heft {heft}"
    );
}

#[test]
fn stragglers_under_every_scheduler_still_complete() {
    let jobs = workload::poisson(2.0, 120, &[], 83);
    for s in SchedulerKind::ALL {
        let mut cfg = ClusterConfig::default().with_scheduler(s);
        cfg.straggler_prob = 0.25;
        cfg.straggler_factor = 8.0;
        let m = Simulator::simulate(cfg, jobs.clone()).metrics;
        assert_eq!(m.jobs.len(), 120, "{s:?}");
    }
}
