//! Parallel experiment engine determinism: every figure computed on a
//! multi-thread `Runner` must be *byte-identical* (f64 bit patterns, not
//! approximate equality) to the serial engine. This is the contract that
//! lets `--threads N` be a pure wall-clock knob — the paper tables never
//! change with core count.

use compass::exp::{fig10, fig6, fig8, Runner, Scale};

fn scale() -> Scale {
    // Small enough for debug-mode CI, large enough that every scheduler
    // actually queues work at the high rates.
    Scale { jobs: 60, seed: 42 }
}

fn bits(rows: &[Vec<f64>]) -> Vec<Vec<u64>> {
    rows.iter().map(|r| r.iter().map(|x| x.to_bits()).collect()).collect()
}

#[test]
fn fig6c_rate_sweep_parallel_matches_serial() {
    let serial = fig6::compute_rate_sweep(&Runner::new(1), scale());
    let parallel = fig6::compute_rate_sweep(&Runner::new(4), scale());
    assert_eq!(serial.rates, parallel.rates);
    assert_eq!(bits(&serial.means), bits(&parallel.means));
}

#[test]
fn fig8_staleness_grid_parallel_matches_serial() {
    let serial = fig8::compute_with(&Runner::new(1), scale());
    let parallel = fig8::compute_with(&Runner::new(4), scale());
    assert_eq!(serial.intervals_ms, parallel.intervals_ms);
    assert_eq!(bits(&serial.slowdown), bits(&parallel.slowdown));
}

#[test]
fn fig10_scalability_parallel_matches_serial() {
    let serial = fig10::compute_with(&Runner::new(1), scale(), true);
    let parallel = fig10::compute_with(&Runner::new(4), scale(), true);
    for (s, p) in serial.compass.iter().zip(&parallel.compass) {
        assert_eq!(s.workers, p.workers);
        assert_eq!(s.active_workers, p.active_workers);
        assert_eq!(s.median_slowdown.to_bits(), p.median_slowdown.to_bits());
    }
    for (s, p) in serial.hash.iter().zip(&parallel.hash) {
        assert_eq!(s.workers, p.workers);
        assert_eq!(s.active_workers, p.active_workers);
        assert_eq!(s.median_slowdown.to_bits(), p.median_slowdown.to_bits());
    }
    assert_eq!(serial.compass.len(), parallel.compass.len());
    assert_eq!(serial.hash.len(), parallel.hash.len());
}

#[test]
fn thread_count_beyond_item_count_is_harmless() {
    // More threads than cells: excess threads find the cursor exhausted.
    let serial = fig6::compute_boxes(&Runner::new(1), 0.5, scale());
    let wide = fig6::compute_boxes(&Runner::new(32), 0.5, scale());
    assert_eq!(serial.per_sched.len(), wide.per_sched.len());
    for ((s_kind, s_rows), (p_kind, p_rows)) in serial.per_sched.iter().zip(&wide.per_sched) {
        assert_eq!(s_kind, p_kind);
        assert_eq!(s_rows.len(), p_rows.len());
        for ((sk, sb), (pk, pb)) in s_rows.iter().zip(p_rows) {
            assert_eq!(sk, pk);
            assert_eq!(sb.median.to_bits(), pb.median.to_bits());
            assert_eq!(sb.q1.to_bits(), pb.q1.to_bits());
            assert_eq!(sb.q3.to_bits(), pb.q3.to_bits());
        }
    }
}
