//! End-to-end observability pipeline: traced simulation → span/decision
//! integrity → Chrome trace_event export → Prometheus snapshot.

use compass::config::ClusterConfig;
use compass::obs::chrome::chrome_trace;
use compass::obs::prom::prometheus_snapshot;
use compass::obs::TraceEvent;
use compass::util::json::Json;
use compass::{workload, SimReport, Simulator};

fn traced_run() -> SimReport {
    let mut cfg = ClusterConfig::default();
    cfg.trace.enabled = true;
    Simulator::simulate(cfg, workload::poisson(2.0, 25, &[], 17))
}

#[test]
fn span_counts_match_completed_work() {
    let rep = traced_run();
    assert_eq!(rep.metrics.incomplete, 0);
    let t = &rep.trace;
    assert_eq!(t.dropped, 0, "25 jobs must fit the default ring");

    // One JobArrive and one JobComplete per job.
    let arrives = t.count(|e| matches!(e, TraceEvent::JobArrive { .. }));
    let completes = t.count(|e| matches!(e, TraceEvent::JobComplete { .. }));
    assert_eq!(arrives, rep.metrics.jobs.len());
    assert_eq!(completes, rep.metrics.jobs.len());

    // Every ExecStart has its ExecEnd and TaskEnqueue: full spans.
    let starts = t.count(|e| matches!(e, TraceEvent::ExecStart { .. }));
    let ends = t.count(|e| matches!(e, TraceEvent::ExecEnd { .. }));
    assert_eq!(starts, ends);
    let spans = t.task_spans();
    assert_eq!(spans.len(), ends);
    // Tasks per job ≥ 1, so spans ≥ jobs; ordering within each span holds.
    assert!(spans.len() >= rep.metrics.jobs.len());
    for s in &spans {
        assert!(s.enqueue_us <= s.start_us && s.start_us <= s.end_us);
    }

    // Fetch spans pair up and match the miss count (each miss = one fetch).
    let fetch_starts = t.count(|e| matches!(e, TraceEvent::FetchStart { .. }));
    let fetch_ends = t.count(|e| matches!(e, TraceEvent::FetchEnd { .. }));
    assert_eq!(fetch_starts, fetch_ends);
    assert_eq!(t.fetch_spans().len(), fetch_ends);
    let misses: u64 = rep.metrics.workers.iter().map(|w| w.misses).sum();
    assert_eq!(fetch_starts as u64, misses);

    // Cache accounting in the trace matches the aggregate counters.
    let hits: u64 = rep.metrics.workers.iter().map(|w| w.hits).sum();
    assert_eq!(t.count(|e| matches!(e, TraceEvent::CacheHit { .. })) as u64, hits);
    assert_eq!(t.count(|e| matches!(e, TraceEvent::CacheMiss { .. })) as u64, misses);
}

#[test]
fn decisions_carry_scored_candidates() {
    let rep = traced_run();
    let mut plan = 0;
    let mut adjust = 0;
    for ev in &rep.trace.events {
        if let TraceEvent::Decision { phase, chosen, candidates, .. } = ev {
            match phase {
                compass::obs::SchedPhase::Plan => plan += 1,
                compass::obs::SchedPhase::Adjust => adjust += 1,
            }
            assert!(!candidates.is_empty(), "every decision scored someone");
            assert!(candidates.total as usize >= candidates.len());
            // Compass always scores the worker it picks.
            assert!(candidates.contains(*chosen), "chosen {chosen} not among candidates");
        }
    }
    assert!(plan > 0, "planning decisions recorded");
    assert!(adjust > 0, "adjustment decisions recorded");
}

#[test]
fn chrome_export_is_valid_and_complete() {
    let rep = traced_run();
    let out = chrome_trace(&rep.trace);
    let json = Json::parse(&out).expect("exporter must emit valid JSON");
    let events = json.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
    assert!(!events.is_empty());

    let mut cats = std::collections::BTreeSet::new();
    let mut decision_with_scores = false;
    for ev in events {
        if let Some(cat) = ev.get("cat").and_then(|c| c.as_str()) {
            cats.insert(cat.to_string());
        }
        if ev.get("cat").and_then(|c| c.as_str()) == Some("sched") {
            let args = ev.get("args").expect("decision args");
            let cands = args.get("candidates").and_then(|c| c.as_arr()).expect("candidates");
            if cands.iter().any(|c| c.get("score_us").and_then(|s| s.as_u64()).is_some()) {
                decision_with_scores = true;
            }
        }
    }
    // The acceptance criterion: queue / fetch / execute phases + decisions.
    for want in ["queue", "exec", "fetch", "sched", "job"] {
        assert!(cats.contains(want), "missing category {want}; have {cats:?}");
    }
    assert!(decision_with_scores, "decision events must carry candidate scores");
}

#[test]
fn prometheus_snapshot_covers_phases() {
    let rep = traced_run();
    let out = prometheus_snapshot(&rep.metrics, Some(&rep.trace));
    for series in [
        "compass_jobs_completed_total",
        "compass_job_latency_seconds_bucket",
        "compass_task_queue_wait_seconds_count",
        "compass_task_exec_seconds_count",
        "compass_model_fetch_seconds_count",
        "compass_sst_staleness_seconds_count",
        "compass_worker_cache_hits_total",
    ] {
        assert!(out.contains(series), "missing series {series}");
    }
    // Exactly one completed job per JobComplete event.
    let line = out
        .lines()
        .find(|l| l.starts_with("compass_jobs_completed_total "))
        .expect("jobs completed sample");
    let v: f64 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    assert_eq!(v as usize, rep.metrics.jobs.len());
}

#[test]
fn disabled_tracing_yields_empty_trace_and_same_results() {
    let jobs = workload::poisson(2.0, 25, &[], 17);
    let off = Simulator::simulate(ClusterConfig::default(), jobs.clone());
    assert!(off.trace.is_empty());

    // Tracing must be observe-only: identical scheduling with it on.
    let mut cfg = ClusterConfig::default();
    cfg.trace.enabled = true;
    let on = Simulator::simulate(cfg, jobs);
    assert_eq!(off.events_processed, on.events_processed);
    assert_eq!(off.sim_span_us, on.sim_span_us);
    let lat_off: Vec<_> = off.metrics.jobs.iter().map(|j| j.latency_us()).collect();
    let lat_on: Vec<_> = on.metrics.jobs.iter().map(|j| j.latency_us()).collect();
    assert_eq!(lat_off, lat_on);
}
