//! Fixture-driven tests of the `compass-lint` engine (DESIGN.md §8):
//! every rule must fire exactly where a seeded violation sits, waivers
//! must suppress, out-of-scope files must stay silent, `#[cfg(test)]`
//! regions are exempt — and the crate's own `src/` tree must lint clean,
//! which makes `cargo test` itself enforce the invariants CI gates on.

use compass::lint::{lint_sources, lint_tree, Finding, Rule};

fn files(list: &[(&str, &str)]) -> Vec<(String, String)> {
    list.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect()
}

fn lines_of(findings: &[Finding], rule: Rule) -> Vec<(String, u32)> {
    findings
        .iter()
        .filter(|f| f.rule == rule)
        .map(|f| (f.file.clone(), f.line))
        .collect()
}

// ---------------------------------------------------------------- L1

#[test]
fn l1_fires_on_each_determinism_hazard_at_exact_lines() {
    let src = "use std::time::Instant;\n\
               use std::collections::HashMap;\n\
               fn ok() {}\n\
               fn t() { let _ = thread_rng(); }\n\
               use std::time::SystemTime;\n";
    for dir in ["sim", "sched", "exp", "obs"] {
        let f = lint_sources(&files(&[(&format!("{dir}/fx.rs"), src)]));
        let got = lines_of(&f, Rule::Determinism);
        assert_eq!(
            got.iter().map(|(_, l)| *l).collect::<Vec<_>>(),
            vec![1, 2, 4, 5],
            "L1 lines in {dir}/"
        );
    }
}

#[test]
fn l1_silent_outside_scope() {
    let src = "use std::time::Instant;\nuse std::collections::HashMap;\n";
    for dir in ["util", "coordinator", "runtime", "gpu"] {
        let f = lint_sources(&files(&[(&format!("{dir}/fx.rs"), src)]));
        assert!(lines_of(&f, Rule::Determinism).is_empty(), "{dir}/ must be out of L1 scope");
    }
}

#[test]
fn l1_waivers_suppress_on_same_or_preceding_line() {
    let src = "// lint: sorted\n\
               use std::collections::HashMap;\n\
               use std::time::Instant; // lint: wall-clock\n";
    let f = lint_sources(&files(&[("sim/fx.rs", src)]));
    assert!(lines_of(&f, Rule::Determinism).is_empty(), "waived lines must not fire: {f:?}");
}

#[test]
fn l1_wrong_waiver_kind_does_not_suppress() {
    // A `sorted` waiver must not excuse a wall-clock hazard.
    let src = "// lint: sorted\nuse std::time::Instant;\n";
    let f = lint_sources(&files(&[("sim/fx.rs", src)]));
    assert_eq!(lines_of(&f, Rule::Determinism), vec![("sim/fx.rs".to_string(), 2)]);
}

#[test]
fn l1_ignores_strings_comments_and_test_modules() {
    let src = "fn a() { let _ = \"Instant::now() HashMap\"; }\n\
               // a comment mentioning SystemTime and HashSet\n\
               #[cfg(test)]\n\
               mod tests {\n\
                   use std::collections::HashMap;\n\
                   use std::time::Instant;\n\
               }\n";
    let f = lint_sources(&files(&[("obs/fx.rs", src)]));
    assert!(f.is_empty(), "strings/comments/test modules must be exempt: {f:?}");
}

// ---------------------------------------------------------------- L2

#[test]
fn l2_fires_inside_fence_only() {
    let src = "fn cold() { let v: Vec<u32> = Vec::new(); let s = format!(\"x\"); drop((v, s)); }\n\
               // lint: hot-path\n\
               fn hot(xs: &[u32]) -> Vec<u32> {\n\
                   let v = Vec::new();\n\
                   let s = format!(\"x\");\n\
                   let c = xs.to_vec().clone();\n\
                   let w: Vec<u32> = xs.iter().copied().collect();\n\
                   drop((v, s, c)); w\n\
               }\n\
               // lint: end-hot-path\n\
               fn cold2() { let _ = vec![1]; }\n";
    let f = lint_sources(&files(&[("sim/fx.rs", src)]));
    let got: Vec<u32> = lines_of(&f, Rule::HotPathAlloc).iter().map(|(_, l)| *l).collect();
    // Line 4: Vec::new; line 5: format!; line 6: .to_vec and .clone;
    // line 7: .collect. Lines 1 and 11 are outside the fence.
    assert_eq!(got, vec![4, 5, 6, 6, 7]);
}

#[test]
fn l2_alloc_ok_waiver_suppresses() {
    let src = "// lint: hot-path\n\
               fn hot() {\n\
                   // lint: alloc-ok\n\
                   let v: Vec<u32> = Vec::new();\n\
                   drop(v);\n\
               }\n\
               // lint: end-hot-path\n";
    let f = lint_sources(&files(&[("sim/fx.rs", src)]));
    assert!(lines_of(&f, Rule::HotPathAlloc).is_empty(), "{f:?}");
}

#[test]
fn l2_unbalanced_and_unknown_directives_are_findings() {
    let unclosed = lint_sources(&files(&[("sim/a.rs", "// lint: hot-path\nfn a() {}\n")]));
    assert_eq!(unclosed.len(), 1);
    assert!(unclosed[0].message.contains("never closed"));

    let stray = lint_sources(&files(&[("sim/b.rs", "fn a() {}\n// lint: end-hot-path\n")]));
    assert_eq!(stray.len(), 1);
    assert!(stray[0].message.contains("without a matching"));

    let typo = lint_sources(&files(&[("sim/c.rs", "// lint: hotpath\nfn a() {}\n")]));
    assert_eq!(typo.len(), 1);
    assert!(typo[0].message.contains("unknown lint directive"));
}

// ---------------------------------------------------------------- L3

#[test]
fn l3_fires_on_lock_and_channel_unwraps_in_coordinator() {
    let src = "use std::sync::{Mutex, mpsc::Receiver};\n\
               fn a(m: &Mutex<u32>, rx: &Receiver<u32>) {\n\
                   let g = m.lock().unwrap();\n\
                   let v = rx.recv().expect(\"worker died\");\n\
                   drop((g, v));\n\
               }\n";
    let f = lint_sources(&files(&[("coordinator/fx.rs", src)]));
    let got: Vec<u32> = lines_of(&f, Rule::PanicHygiene).iter().map(|(_, l)| *l).collect();
    assert_eq!(got, vec![3, 4]);
}

#[test]
fn l3_silent_on_handled_results_and_outside_coordinator() {
    let handled = "use std::sync::Mutex;\n\
                   fn a(m: &Mutex<u32>) {\n\
                       match m.lock() { Ok(g) => drop(g), Err(p) => drop(p.into_inner()) }\n\
                       let _ = m.lock().unwrap_or_else(|p| p.into_inner());\n\
                   }\n";
    assert!(lint_sources(&files(&[("coordinator/fx.rs", handled)])).is_empty());

    let unwrap = "fn a(m: &std::sync::Mutex<u32>) { let _ = m.lock().unwrap(); }\n";
    assert!(lint_sources(&files(&[("sim/fx.rs", unwrap)]))
        .iter()
        .all(|f| f.rule != Rule::PanicHygiene));
}

#[test]
fn l3_may_panic_waiver_suppresses() {
    let src = "// lint: may-panic\n\
               fn a(m: &std::sync::Mutex<u32>) { let _ = m.lock().unwrap(); }\n";
    assert!(lint_sources(&files(&[("coordinator/fx.rs", src)])).is_empty());
}

// ---------------------------------------------------------------- L4

#[test]
fn l4_flags_unhandled_variants_per_exporter() {
    let fx = files(&[
        (
            "obs/mod.rs",
            "pub enum TraceEvent {\n    JobArrive { t: u64 },\n    #[allow(dead_code)]\n    CacheHit { worker: u16 },\n    BatchFormed(u16),\n}\n",
        ),
        (
            "obs/chrome.rs",
            "fn f(e: &TraceEvent) { match e {\n TraceEvent::JobArrive { .. } => {}\n TraceEvent::CacheHit { .. } => {}\n TraceEvent::BatchFormed(_) => {}\n} }\n",
        ),
        ("obs/prom.rs", "fn f(e: &TraceEvent) { if let TraceEvent::JobArrive { .. } = e {} }\n"),
    ]);
    let f = lint_sources(&fx);
    let l4 = lines_of(&f, Rule::ExporterExhaustive);
    assert_eq!(l4.len(), 2, "{f:?}");
    assert!(l4.iter().all(|(file, _)| file == "obs/prom.rs"));
    assert!(f.iter().any(|x| x.message.contains("TraceEvent::CacheHit")));
    assert!(f.iter().any(|x| x.message.contains("TraceEvent::BatchFormed")));
}

#[test]
fn l4_clean_when_both_exporters_cover_all_variants() {
    let fx = files(&[
        ("obs/mod.rs", "pub enum TraceEvent { A { t: u64 }, B(u16) }\n"),
        ("obs/chrome.rs", "fn f(e: &TraceEvent) { match e { TraceEvent::A { .. } => {} TraceEvent::B(_) => {} } }\n"),
        ("obs/prom.rs", "fn g(e: &TraceEvent) { match e { TraceEvent::A { .. } => \"a\", TraceEvent::B(_) => \"b\" }; }\n"),
    ]);
    let f = lint_sources(&fx);
    assert!(lines_of(&f, Rule::ExporterExhaustive).is_empty(), "{f:?}");
}

// ---------------------------------------------------------------- L5

#[test]
fn l5_fires_on_raw_partial_cmp_unwrap_everywhere() {
    let src = "fn s(v: &mut [f64]) {\n\
                   v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                   v.sort_by(|a, b| a.partial_cmp(b).expect(\"nan\"));\n\
               }\n";
    for dir in ["util", "sim", "coordinator"] {
        let f = lint_sources(&files(&[(&format!("{dir}/fx.rs"), src)]));
        let got: Vec<u32> = lines_of(&f, Rule::FloatOrdering).iter().map(|(_, l)| *l).collect();
        assert_eq!(got, vec![2, 3], "L5 in {dir}/");
    }
}

#[test]
fn l5_ignores_trait_impls_and_honors_waiver() {
    let imp = "impl PartialOrd for S {\n\
                   fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> { Some(self.cmp(o)) }\n\
               }\n";
    assert!(lint_sources(&files(&[("sim/fx.rs", imp)])).is_empty());

    let waived = "fn s(v: &mut [f64]) {\n\
                      // lint: total-order\n\
                      v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
                  }\n";
    assert!(lint_sources(&files(&[("sim/fx.rs", waived)])).is_empty());
}

// ------------------------------------------------------- self-hosting

/// The tentpole acceptance gate, enforced from `cargo test` itself: the
/// crate's own sources must produce zero findings. CI additionally runs
/// `cargo run --release -- lint` as a separate job.
#[test]
fn crate_sources_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&root).expect("lint_tree walks src/");
    assert!(report.files_scanned >= 30, "expected the full tree, saw {}", report.files_scanned);
    assert!(report.clean(), "compass-lint findings in tree:\n{}", report.render());
}

/// The real exporter-exhaustiveness invariant, checked against the real
/// sources: obs/mod.rs's TraceEvent enum parses to the 20 known variants.
#[test]
fn l4_sees_the_real_trace_event_enum() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let src = std::fs::read_to_string(root.join("obs/mod.rs")).expect("obs/mod.rs");
    let scanned = compass::lint::scan::scan(&src);
    let variants = compass::lint::rules::enum_variants(&scanned.toks, "TraceEvent");
    let names: Vec<&str> = variants.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(
        names,
        vec![
            "JobArrive",
            "JobComplete",
            "TaskEnqueue",
            "ExecStart",
            "ExecEnd",
            "FetchStart",
            "FetchEnd",
            "Decision",
            "CacheHit",
            "CacheMiss",
            "CacheInsert",
            "CacheEvict",
            "SstStaleness",
            "BatchFormed",
            "BatchExecuted",
            "WorkerFailed",
            "TaskRetried",
            "TaskRePlaced",
            "JobDegraded",
            "RuntimeLoadFailed",
        ]
    );
}

/// Findings across several files come back sorted by (file, line) so the
/// report (and the CI log) is stable run to run.
#[test]
fn findings_are_reported_in_stable_order() {
    let fx = files(&[
        ("sim/z.rs", "use std::collections::HashMap;\nuse std::time::Instant;\n"),
        ("obs/a.rs", "use std::collections::HashSet;\n"),
    ]);
    let f = lint_sources(&fx);
    let order: Vec<(String, u32)> = f.iter().map(|x| (x.file.clone(), x.line)).collect();
    assert_eq!(
        order,
        vec![
            ("obs/a.rs".to_string(), 1),
            ("sim/z.rs".to_string(), 1),
            ("sim/z.rs".to_string(), 2),
        ]
    );
}
