//! Integration: live coordinator end-to-end, including the §5.4
//! simulator-vs-live validation (with real PJRT execution when artifacts
//! are present).

use compass::config::{ClusterConfig, SchedulerKind};
use compass::coordinator::{LiveCluster, LiveConfig};
use compass::exp::validate;
use compass::runtime::artifacts_dir;
use compass::workload;
use std::time::Duration;

fn fast_live() -> LiveConfig {
    LiveConfig { time_scale: 300.0, wall_timeout: Duration::from_secs(120) }
}

#[test]
fn live_completes_mixed_workload() {
    let jobs = workload::poisson(2.0, 25, &[], 31);
    let rep = LiveCluster::run(ClusterConfig::default().with_seed(31), fast_live(), None, jobs)
        .expect("live run");
    assert_eq!(rep.metrics.jobs.len(), 25);
    assert!(rep.metrics.mean_slowdown() >= 0.8);
    assert!(rep.metrics.active_workers() >= 1);
}

#[test]
fn live_compass_beats_hash_same_stream() {
    let jobs = workload::poisson(2.5, 30, &[], 17);
    let c = LiveCluster::run(
        ClusterConfig::default().with_seed(17),
        fast_live(),
        None,
        jobs.clone(),
    )
    .unwrap();
    let h = LiveCluster::run(
        ClusterConfig::default().with_scheduler(SchedulerKind::Hash).with_seed(17),
        fast_live(),
        None,
        jobs,
    )
    .unwrap();
    // Generous margin: live mode has wall-clock noise.
    assert!(
        c.metrics.mean_slowdown() < h.metrics.mean_slowdown() * 1.15,
        "compass {} vs hash {}",
        c.metrics.mean_slowdown(),
        h.metrics.mean_slowdown()
    );
}

#[test]
fn validation_sim_vs_live_close() {
    // The paper's §5.4: simulator within ~5% of the real system. We allow
    // 25% in CI (coarse thread scheduling at 300x time compression).
    let r = validate::run(30, 42, None).expect("validation run");
    assert!(
        r.within_tolerance(0.25),
        "sim/live diverged: {}",
        r.render()
    );
}

#[test]
fn live_with_pjrt_executes_real_models() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let jobs = workload::poisson(2.0, 10, &[], 5);
    let live = LiveConfig { time_scale: 100.0, wall_timeout: Duration::from_secs(240) };
    let rep = LiveCluster::run(ClusterConfig::default().with_seed(5), live, Some(dir), jobs)
        .expect("live run with PJRT");
    assert_eq!(rep.metrics.jobs.len(), 10);
    // Every model-bearing vertex triggers one PJRT forward pass.
    assert!(
        rep.pjrt_executions >= 10,
        "expected real executions, got {}",
        rep.pjrt_executions
    );
}
