//! Property-based tests on coordinator invariants: routing, batching/queue
//! state, cache accounting, SST staleness, and whole-simulation sanity —
//! driven by the in-tree property harness (`util::prop`, seeded + replayable).

use compass::config::{ClusterConfig, CompassConfig, SchedulerKind};
use compass::core::{Micros, GB};
use compass::dfg::{models, pipelines, Job, PipelineKind};
use compass::gpu::{EvictionPolicy, GpuCache};
use compass::net::CostModel;
use compass::sched::{self, ClusterView, Scheduler};
use compass::sst::SstRow;
use compass::util::prop::check;
use compass::util::rng::Rng;
use compass::{workload, Simulator};

fn random_rows(rng: &mut Rng, n: usize) -> Vec<SstRow> {
    (0..n)
        .map(|_| SstRow {
            ft_us: rng.below(20_000_000),
            cache_bitmap: rng.next_u64() & 0xff,
            free_cache_bytes: rng.below(16 * GB),
            load_pushed_at: 0,
            cache_pushed_at: 0,
        })
        .collect()
}

fn random_job(rng: &mut Rng) -> Job {
    Job {
        id: rng.next_u64() % 10_000,
        kind: PipelineKind::from_index(rng.below(4) as usize),
        arrival_us: rng.below(100_000_000),
        input_bytes: 1 + rng.below(1_000_000),
    }
}

// ---------------------------------------------------------------- routing

#[test]
fn prop_plan_routes_every_task_to_valid_worker() {
    check("plan-valid-routing", 1, |rng| {
        let n_workers = 1 + rng.below(16) as usize;
        let kind = SchedulerKind::ALL[rng.below(4) as usize];
        let cfg = ClusterConfig::default().with_scheduler(kind).with_workers(n_workers);
        let sched = sched::build(&cfg);
        let cost = CostModel::default();
        let dfg = pipelines::by_kind(PipelineKind::from_index(rng.below(4) as usize), &cost);
        let rows = random_rows(rng, n_workers);
        let speed = vec![1.0; n_workers];
        let job = random_job(rng);
        let view = ClusterView {
            now: job.arrival_us,
            self_worker: rng.below(n_workers as u64) as usize,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &sched::PlanCell::default(),
        };
        let adfg = sched.plan(&job, &dfg, &view);
        if adfg.assignment.len() != dfg.len() {
            return Err("wrong ADFG length".into());
        }
        for (t, a) in adfg.assignment.iter().enumerate() {
            match (kind, a) {
                (SchedulerKind::Jit, None) => {}
                (SchedulerKind::Jit, Some(_)) => {
                    return Err("JIT must not pre-assign".into());
                }
                (_, Some(w)) if *w < n_workers => {}
                _ => return Err(format!("task {t} badly assigned: {a:?}")),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_planning_is_deterministic_given_view() {
    check("plan-deterministic", 2, |rng| {
        let n_workers = 1 + rng.below(8) as usize;
        let cfg = ClusterConfig::default().with_workers(n_workers);
        let sched = sched::build(&cfg);
        let cost = CostModel::default();
        let dfg = pipelines::translation(&cost);
        let rows = random_rows(rng, n_workers);
        let speed = vec![1.0; n_workers];
        let job = random_job(rng);
        let view = ClusterView {
            now: job.arrival_us,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &sched::PlanCell::default(),
        };
        let a = sched.plan(&job, &dfg, &view);
        let b = sched.plan(&job, &dfg, &view);
        if a.assignment != b.assignment {
            return Err("same view, different plans".into());
        }
        Ok(())
    });
}

// ------------------------------------------------------------ cache state

#[test]
fn prop_cache_accounting_never_overflows() {
    check("cache-accounting", 3, |rng| {
        let cap = 16 * GB;
        let policy = if rng.f64() < 0.5 {
            EvictionPolicy::Fifo
        } else {
            EvictionPolicy::QueueLookahead { window: 1 + rng.below(20) as usize }
        };
        let mut cache = GpuCache::new(cap, policy);
        let mut t: Micros = 0;
        for _ in 0..200 {
            t += rng.below(1000);
            let m = rng.below(8) as u8;
            let lookahead: Vec<u8> = (0..rng.below(10)).map(|_| rng.below(8) as u8).collect();
            if cache.contains(m) {
                if rng.f64() < 0.3 {
                    cache.evict(m, t);
                }
                continue;
            }
            let need = models::model_bytes(m);
            if let Some(victims) = cache.plan_eviction(need, &lookahead) {
                for v in victims {
                    cache.evict(v, t);
                }
                cache.insert(m, t);
            }
            // Invariants.
            if cache.used() > cap {
                return Err(format!("over capacity: {}", cache.used()));
            }
            let sum: u64 = cache.resident().iter().map(|&x| models::model_bytes(x)).sum();
            if sum != cache.used() {
                return Err(format!("byte accounting drift: {} vs {}", sum, cache.used()));
            }
            let bm = cache.bitmap();
            for &x in cache.resident() {
                if bm & (1 << x) == 0 {
                    return Err("bitmap missing resident".into());
                }
            }
            if bm.count_ones() as usize != cache.resident().len() {
                return Err("bitmap has ghost".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_eviction_plan_is_sufficient_and_minimal_order() {
    check("eviction-plan-sufficient", 4, |rng| {
        let mut cache = GpuCache::new(16 * GB, EvictionPolicy::Fifo);
        // Fill with random distinct models.
        let mut ms: Vec<u8> = (0..8).collect();
        rng.shuffle(&mut ms);
        for &m in ms.iter().take(3) {
            if models::model_bytes(m) <= cache.free_bytes() {
                cache.insert(m, 0);
            }
        }
        let need = 1 + rng.below(10 * GB);
        if let Some(victims) = cache.plan_eviction(need, &[]) {
            let freed: u64 = victims.iter().map(|&v| models::model_bytes(v)).sum();
            if cache.free_bytes() + freed < need {
                return Err("plan frees too little".into());
            }
            // All victims resident and distinct.
            let mut seen = std::collections::HashSet::new();
            for v in &victims {
                if !cache.contains(*v) || !seen.insert(*v) {
                    return Err("bad victim".into());
                }
            }
        } else if need <= cache.used() + cache.free_bytes() {
            return Err("refused although possible (nothing pinned)".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- ranking

#[test]
fn prop_ranks_strictly_decrease_along_edges() {
    check("rank-monotone", 5, |rng| {
        let cost = CostModel::default();
        let dfg = pipelines::by_kind(PipelineKind::from_index(rng.below(4) as usize), &cost);
        for t in 0..dfg.len() {
            for &s in &dfg.succs[t] {
                if dfg.ranks[t] <= dfg.ranks[s] {
                    return Err(format!("rank({t}) <= rank(succ {s})"));
                }
            }
        }
        // Rank order must be a topological order.
        let order = dfg.rank_order();
        let pos: Vec<usize> =
            (0..dfg.len()).map(|t| order.iter().position(|&x| x == t).unwrap()).collect();
        for t in 0..dfg.len() {
            for &s in &dfg.succs[t] {
                if pos[t] >= pos[s] {
                    return Err("rank order not topological".into());
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------- simulation

#[test]
fn prop_simulation_conserves_jobs_and_time() {
    check("sim-conservation", 6, |rng| {
        let n_jobs = 10 + rng.below(40) as usize;
        let rate = 0.5 + rng.f64() * 3.0;
        let kind = SchedulerKind::ALL[rng.below(4) as usize];
        let n_workers = 2 + rng.below(8) as usize;
        let seed = rng.next_u64();
        let cfg = ClusterConfig::default()
            .with_scheduler(kind)
            .with_workers(n_workers)
            .with_seed(seed);
        let jobs = workload::poisson(rate, n_jobs, &[], seed ^ 1);
        let arrival_max = jobs.last().unwrap().arrival_us;
        let rep = Simulator::simulate(cfg, jobs);
        let m = rep.metrics;
        if m.jobs.len() != n_jobs {
            return Err(format!("{} of {n_jobs} jobs completed", m.jobs.len()));
        }
        for j in &m.jobs {
            if j.completion_us < j.arrival_us {
                return Err("completion before arrival".into());
            }
            if j.slowdown() < 0.5 {
                return Err(format!("impossible slowdown {}", j.slowdown()));
            }
        }
        if m.span_us < arrival_max {
            return Err("span ends before last arrival".into());
        }
        // Busy time can never exceed span per worker.
        for w in &m.workers {
            if w.busy_us > m.span_us {
                return Err("worker busier than wall time".into());
            }
        }
        // Hit + miss = fetch-relevant starts; fetches <= misses (each miss
        // triggers at most one fetch) and fetches == misses here.
        let hits: u64 = m.workers.iter().map(|w| w.hits).sum();
        let misses: u64 = m.workers.iter().map(|w| w.misses).sum();
        let fetches: u64 = m.workers.iter().map(|w| w.fetches).sum();
        if fetches != misses {
            return Err(format!("fetches {fetches} != misses {misses}"));
        }
        if hits + misses == 0 {
            return Err("no model activity at all".into());
        }
        Ok(())
    });
}

#[test]
fn prop_ablated_compass_still_correct() {
    check("ablation-correctness", 7, |rng| {
        let mut cfg = ClusterConfig::default().with_seed(rng.next_u64());
        cfg.compass = CompassConfig {
            dynamic_adjust: rng.f64() < 0.5,
            model_locality: rng.f64() < 0.5,
            adjust_threshold: 0.5 + rng.f64() * 4.0,
            eviction_penalty_factor: rng.f64() * 3.0,
        };
        if rng.f64() < 0.5 {
            cfg.eviction = EvictionPolicy::Fifo;
        }
        let jobs = workload::poisson(2.0, 30, &[], rng.next_u64());
        let m = Simulator::simulate(cfg, jobs).metrics;
        if m.jobs.len() != 30 {
            return Err("ablated config lost jobs".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sim_deterministic_across_runs() {
    check("sim-determinism", 8, |rng| {
        let seed = rng.next_u64();
        let jobs = workload::poisson(1.5, 25, &[], seed);
        let cfg = ClusterConfig::default().with_seed(seed);
        let a = Simulator::simulate(cfg.clone(), jobs.clone());
        let b = Simulator::simulate(cfg, jobs);
        if a.events_processed != b.events_processed || a.sim_span_us != b.sim_span_us {
            return Err("nondeterministic simulation".into());
        }
        let la: Vec<Micros> = a.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        let lb: Vec<Micros> = b.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        if la != lb {
            return Err("latencies differ between identical runs".into());
        }
        Ok(())
    });
}

// -------------------------------------------------------------------- sst

#[test]
fn prop_sst_reader_never_sees_unpushed_state() {
    check("sst-staleness-bound", 9, |rng| {
        use compass::sst::Sst;
        let n = 2 + rng.below(10) as usize;
        let mut sst = Sst::new(n);
        let mut last_pushed = vec![(0u64, 0u64); n]; // (ft, time)
        let mut t: Micros = 0;
        for _ in 0..100 {
            t += rng.below(50_000);
            let w = rng.below(n as u64) as usize;
            if rng.f64() < 0.5 {
                let ft = t + rng.below(1_000_000);
                sst.push_load(w, ft, t);
                last_pushed[w] = (ft, t);
            } else {
                // Read: must exactly equal the last pushed value.
                let row = sst.row(w);
                if row.ft_us != last_pushed[w].0 || row.load_pushed_at != last_pushed[w].1 {
                    return Err("reader observed unpushed state".into());
                }
                if sst.max_load_staleness(t) > t {
                    return Err("staleness exceeds elapsed time".into());
                }
            }
        }
        Ok(())
    });
}
