//! Property tests on the execute-path batching invariants: `batch_max = 1`
//! is bit-identical to the unbatched simulator, formed batches never mix
//! models or exceed `batch_max`, every batch member completes exactly at
//! the batch end instant, and every scheduler still completes all jobs
//! with batching on — driven by the in-tree harness (`util::prop`).

use std::collections::HashMap;

use compass::config::{ClusterConfig, SchedulerKind};
use compass::core::{JobId, Micros, ModelId};
use compass::dfg::pipelines;
use compass::net::CostModel;
use compass::obs::TraceEvent;
use compass::util::prop::check;
use compass::{workload, Simulator};

/// `batch_max = 1` — whatever the window or alpha override — must leave
/// every observable of the simulation untouched, down to the bit.
#[test]
fn prop_batch_max_one_is_bit_identical_to_unbatched() {
    check("batching-off-identity", 21, |rng| {
        let n_jobs = 10 + rng.below(30) as usize;
        let rate = 0.5 + rng.f64() * 4.0;
        let kind = SchedulerKind::ALL[rng.below(4) as usize];
        let n_workers = 2 + rng.below(8) as usize;
        let seed = rng.next_u64();
        let jobs = workload::poisson(rate, n_jobs, &[], seed ^ 1);

        let base = ClusterConfig::default()
            .with_scheduler(kind)
            .with_workers(n_workers)
            .with_seed(seed);
        let mut off = base.clone().with_batching(1, rng.below(5_000));
        off.cost.batch.alpha_override = Some(rng.f64());

        let a = Simulator::simulate(base, jobs.clone());
        let b = Simulator::simulate(off, jobs);
        if a.events_processed != b.events_processed {
            return Err(format!(
                "event counts diverged: {} vs {}",
                a.events_processed, b.events_processed
            ));
        }
        if a.sim_span_us != b.sim_span_us {
            return Err("sim span diverged".into());
        }
        let la: Vec<Micros> = a.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        let lb: Vec<Micros> = b.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        if la != lb {
            return Err("per-job latencies diverged".into());
        }
        // Derived f64 aggregates must match bit-for-bit, not just approximately.
        if a.metrics.mean_latency_s().to_bits() != b.metrics.mean_latency_s().to_bits()
            || a.metrics.mean_slowdown().to_bits() != b.metrics.mean_slowdown().to_bits()
        {
            return Err("f64 aggregates not bit-identical".into());
        }
        Ok(())
    });
}

/// Trace-level batching invariants. The worker is serial, so all the
/// `ExecStart` events sharing one `(worker, t)` are exactly one dispatch —
/// a batch (or a solo start). Checks: no group exceeds `batch_max`, no
/// multi-member group mixes models (or contains a model-less glue task),
/// each `BatchExecuted` retires exactly `size` members at its instant, and
/// batch completions account for every `ExecEnd` in the run.
#[test]
fn prop_batches_never_mix_models_and_retire_together() {
    check("batching-trace-invariants", 22, |rng| {
        let batch_max = 2 + rng.below(7) as usize; // 2..=8
        let window: Micros = rng.below(3_000);
        let n_jobs = 20 + rng.below(40) as usize;
        let rate = 2.0 + rng.f64() * 4.0;
        let seed = rng.next_u64();
        // Same-model-heavy (VPA-only) stream: the regime that forms batches.
        let jobs = workload::poisson(rate, n_jobs, &[0.0, 0.0, 1.0, 0.0], seed ^ 1);
        let mut cfg = ClusterConfig::default().with_seed(seed).with_batching(batch_max, window);
        cfg.trace.enabled = true;
        let rep = Simulator::simulate(cfg, jobs);
        if rep.metrics.incomplete != 0 {
            return Err(format!("{} jobs incomplete under batching", rep.metrics.incomplete));
        }
        if rep.trace.dropped != 0 {
            return Err("trace ring overflowed; invariants unverifiable".into());
        }

        let cost = CostModel::default();
        let mut kind_of = HashMap::new();
        for ev in &rep.trace.events {
            if let TraceEvent::JobArrive { job, kind, .. } = *ev {
                kind_of.insert(job, kind);
            }
        }
        let model_of = |job: JobId, task: u16| -> Result<Option<ModelId>, String> {
            let kind = kind_of.get(&job).ok_or("ExecStart for job without JobArrive")?;
            Ok(pipelines::by_kind(*kind, &cost).vertices[task as usize].model)
        };

        let mut groups: HashMap<(u16, Micros), Vec<(JobId, u16)>> = HashMap::new();
        for ev in &rep.trace.events {
            if let TraceEvent::ExecStart { job, task, worker, t } = *ev {
                groups.entry((worker, t)).or_default().push((job, task));
            }
        }
        for members in groups.values() {
            if members.len() > batch_max {
                return Err(format!(
                    "dispatch of {} members exceeds batch_max {batch_max}",
                    members.len()
                ));
            }
            if members.len() > 1 {
                let m0 = model_of(members[0].0, members[0].1)?;
                if m0.is_none() {
                    return Err("model-less task coalesced into a batch".into());
                }
                for &(j, task) in members {
                    if model_of(j, task)? != m0 {
                        return Err("batch mixes models".into());
                    }
                }
            }
        }

        let mut ends: HashMap<(u16, Micros), usize> = HashMap::new();
        for ev in &rep.trace.events {
            if let TraceEvent::ExecEnd { worker, t, .. } = *ev {
                *ends.entry((worker, t)).or_default() += 1;
            }
        }
        let mut batched = 0usize;
        for ev in &rep.trace.events {
            if let TraceEvent::BatchExecuted { worker, size, t, .. } = *ev {
                batched += size as usize;
                let got = ends.get(&(worker, t)).copied().unwrap_or(0);
                if got != size as usize {
                    return Err(format!(
                        "batch of {size} on worker {worker} retired {got} members at t={t}"
                    ));
                }
            }
        }
        // With batching on, every execution completes through the batch
        // path, so batch sizes must account for every ExecEnd.
        let total_ends: usize = ends.values().sum();
        if batched != total_ends {
            return Err(format!("{batched} batched completions vs {total_ends} ExecEnds"));
        }
        Ok(())
    });
}

/// Batching must not wedge any scheduler: random `batch_max`/window over
/// the standard 4-pipeline mix, every job still completes.
#[test]
fn prop_all_schedulers_complete_under_batching() {
    check("batching-all-schedulers", 23, |rng| {
        let kind = SchedulerKind::ALL[rng.below(4) as usize];
        let cfg = ClusterConfig::default()
            .with_scheduler(kind)
            .with_seed(rng.next_u64())
            .with_batching(2 + rng.below(7) as usize, rng.below(3_000));
        let jobs = workload::poisson(2.0, 25, &[], rng.next_u64());
        let m = Simulator::simulate(cfg, jobs).metrics;
        if m.jobs.len() != 25 {
            return Err(format!("{} of 25 jobs completed under batching", m.jobs.len()));
        }
        Ok(())
    });
}
