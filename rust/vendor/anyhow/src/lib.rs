//! Vendored minimal `anyhow` for the offline build.
//!
//! Implements exactly the subset of the real crate's API that compass
//! uses: `Error`, `Result`, the `anyhow!` / `bail!` macros, the `Context`
//! extension trait, `?`-conversion from any `std::error::Error`, and the
//! `{:#}` alternate Display that prints the context chain. Semantics match
//! the real crate for this subset; nothing else is provided.

use std::error::Error as StdErrorTrait;
use std::fmt;

/// A dynamically typed error with an optional chain of context messages.
///
/// Like the real `anyhow::Error`, this deliberately does NOT implement
/// `std::error::Error` — that is what allows the blanket `From` impl below
/// to exist, which is what makes `?` work on any std error type.
pub struct Error {
    /// Outermost description first (most recently attached context).
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach a higher-level context message (becomes the new outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (what plain `{}` prints).
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost first, ": "-joined.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdErrorTrait + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Flatten the source chain into context entries so `{:#}` shows it.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Sealed helper: anything that can become an [`Error`] so `Context` can be
/// implemented both for std error types and for `Result<T, Error>` itself
/// (mirroring the real crate's `ext::StdError` trick).
mod private {
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoAnyhow for E {
        fn into_anyhow(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoAnyhow for super::Error {
        fn into_anyhow(self) -> super::Error {
            self
        }
    }
}

/// Extension trait providing `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoAnyhow> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing file"));
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn bails() -> Result<()> {
            bail!("nope: {}", "reason");
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope: reason");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "));
        assert!(full.contains("missing file"));
    }

    #[test]
    fn context_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("inner fail");
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner fail");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
    }
}
