//! Offline stub of the `xla` PJRT bindings.
//!
//! The build environment has no XLA/PJRT shared library, so this crate
//! provides just enough surface for `compass::runtime` to typecheck.
//! `PjRtClient::cpu()` always returns an error ("PJRT unavailable"), which
//! the callers already handle gracefully: the live coordinator logs and
//! continues without a runtime, and the runtime tests skip when no
//! artifacts manifest is present. Every method reachable only after a
//! successful `cpu()` is therefore dead code kept for type compatibility.

use std::fmt;

/// The single error type; implements `std::error::Error` so `?` converts it
/// into `anyhow::Error` at the call sites in `compass::runtime`.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }

    fn unavailable() -> Error {
        Error::new("xla/PJRT unavailable: compass was built with the offline stub")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A PJRT device client. The stub cannot construct one.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Always fails in the stub; real builds open the PJRT CPU plugin.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// A compiled executable. Unreachable in the stub (no client exists).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// A device buffer handle. Unreachable in the stub.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A host-side tensor literal. Construction works (it is pure host data);
/// anything that would need XLA itself errors.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec(), dims: vec![v.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error::new("reshape: element count mismatch"));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module text. The stub does not parse; loading always errors,
/// which `Runtime::load` surfaces before any execution is attempted.
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_is_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_reshape_checks_counts() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.reshape(&[2, 2]).unwrap().dims(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
    }
}
