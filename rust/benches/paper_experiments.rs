//! End-to-end benches: one per paper table/figure (`cargo bench`).
//!
//! Each bench regenerates a (quick-scale) version of the corresponding §6
//! artifact and reports wall time. The printed experiment output itself is
//! the reproduction; EXPERIMENTS.md quotes both. `--json FILE` appends
//! machine-readable reports (merged with the micro-bench binary's).

use compass::exp::{self, Scale};
use compass::util::args::Args;
use compass::util::bench::{self, Bench, BenchReport};

fn main() {
    let args = Args::from_env();
    let scale = Scale::quick();
    let mut reports: Vec<BenchReport> = Vec::new();

    println!("\n################ paper experiment benches ################\n");

    reports.push(
        Bench::quick("fig6a_low_load_boxes")
            .run(|| exp::fig6::boxes(0.5, scale, "Figure 6a — low load (0.5 req/s)")),
    );
    reports.push(
        Bench::quick("fig6b_high_load_boxes")
            .run(|| exp::fig6::boxes(2.0, scale, "Figure 6b — high load (2 req/s)")),
    );
    reports.push(Bench::quick("fig6c_rate_sweep").run(|| exp::fig6::rate_sweep(scale)));
    reports.push(Bench::quick("table1_metrics").run(|| exp::table1::run(scale)));
    reports.push(Bench::quick("fig7_ablation").run(|| exp::fig7::run(scale)));
    reports.push(Bench::quick("fig8_staleness").run(|| exp::fig8::run(scale)));
    reports.push(Bench::quick("fig9_trace").run(|| exp::fig9::run(scale)));
    reports.push(Bench::quick("fig10_scalability").run(|| exp::fig10::run(scale, true)));

    if let Some(path) = args.get_path("json") {
        bench::write_json(&path, &reports).expect("write bench json");
        println!("\n{} bench reports written to {}", reports.len(), path.display());
    }
    println!("\nall paper-experiment benches complete");
}
