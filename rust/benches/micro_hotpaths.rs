//! Micro-benches on the L3 hot paths (`cargo bench`).
//!
//! These are the §Perf targets in EXPERIMENTS.md:
//!   * Algorithm 1 planning — O(E·W), runs once per job on the request path.
//!   * Algorithm 2 adjustment — runs once per task completion.
//!   * The simulator event loop — events/second (the scalability experiment
//!     pushes hundreds of thousands of events per run).
//!   * GPU eviction planning — runs on every model fetch.
//!
//! All fixtures (views, scratch cells, workloads, configs) are built once,
//! outside the timed closures — the closures measure only the hot path, not
//! setup clones. `--json FILE` appends machine-readable reports.

use compass::config::{ClusterConfig, SchedulerKind};
use compass::dfg::{pipelines, Job, PipelineKind};
use compass::net::CostModel;
use compass::sched::{self, AssignCtx, ClusterView, PlanCell};
use compass::sst::SstRow;
use compass::util::args::Args;
use compass::util::bench::{self, Bench, BenchReport};
use compass::util::rng::Rng;
use compass::{workload, Simulator};

fn rows(n: usize, rng: &mut Rng) -> Vec<SstRow> {
    (0..n)
        .map(|_| SstRow {
            ft_us: rng.below(5_000_000),
            cache_bitmap: rng.next_u64() & 0xff,
            free_cache_bytes: rng.below(16_000_000_000),
            load_pushed_at: 0,
            cache_pushed_at: 0,
        })
        .collect()
}

fn main() {
    let args = Args::from_env();
    let cost = CostModel::default();
    let mut rng = Rng::new(7);
    let mut reports: Vec<BenchReport> = Vec::new();

    // --- Algorithm 1 planning at paper scale (5 workers) and large scale.
    for &(n_workers, label) in
        &[(5usize, "plan_alg1_translation_w5"), (250usize, "plan_alg1_translation_w250")]
    {
        let cfg = ClusterConfig::default().with_workers(n_workers);
        let sched = sched::build(&cfg);
        let dfg = pipelines::translation(&cost);
        let r = rows(n_workers, &mut rng);
        let speed = vec![1.0; n_workers];
        let job = Job { id: 1, kind: PipelineKind::Translation, arrival_us: 0, input_bytes: 1000 };
        let scratch = PlanCell::default();
        let view = ClusterView {
            now: 1_000_000,
            self_worker: 0,
            rows: &r,
            cost: &cost,
            speed: &speed,
            scratch: &scratch,
        };
        reports.push(Bench::new(label).run(|| sched.plan(&job, &dfg, &view)));
    }

    // --- Algorithm 2 dynamic adjustment (reschedule path).
    {
        let n_workers = 5;
        let cfg = ClusterConfig::default().with_workers(n_workers);
        let sched = sched::build(&cfg);
        let dfg = pipelines::vpa(&cost);
        let mut r = rows(n_workers, &mut rng);
        r[1].ft_us = 60_000_000;
        let speed = vec![1.0; n_workers];
        let job = Job { id: 1, kind: PipelineKind::Vpa, arrival_us: 0, input_bytes: 1000 };
        let outs = [(0usize, 4096u64)];
        let scratch = PlanCell::default();
        let view = ClusterView {
            now: 1_000_000,
            self_worker: 0,
            rows: &r,
            cost: &cost,
            speed: &speed,
            scratch: &scratch,
        };
        let ctx = AssignCtx { job: &job, dfg: &dfg, task: 1, planned: Some(1), pred_outputs: &outs };
        reports.push(Bench::new("adjust_alg2_reschedule_w5").run(|| sched.assign(&ctx, &view)));
    }

    // --- Simulator event-loop throughput at paper scale.
    {
        let jobs = workload::poisson(2.0, 300, &[], 3);
        let cfg = ClusterConfig::default();
        let events = Simulator::simulate_ref(&cfg, &jobs).events_processed;
        let b = Bench::new("sim_300_jobs_5_workers")
            .run(|| Simulator::simulate_ref(&cfg, &jobs))
            .with_events(events);
        println!(
            "  -> ~{:.2} M events/s ({} events per run)",
            b.events_per_sec.unwrap_or(0.0) / 1e6,
            events
        );
        reports.push(b);
    }

    // --- Same workload with the event tracer on: measures observability
    // overhead against sim_300_jobs_5_workers above (the acceptance budget
    // is on the *disabled* path; this shows the enabled cost too).
    {
        let jobs = workload::poisson(2.0, 300, &[], 3);
        let mut cfg = ClusterConfig::default();
        cfg.trace.enabled = true;
        let events = Simulator::simulate_ref(&cfg, &jobs).events_processed;
        let b = Bench::new("sim_300_jobs_traced")
            .run(|| Simulator::simulate_ref(&cfg, &jobs))
            .with_events(events);
        let n_events = Simulator::simulate_ref(&cfg, &jobs).trace.events.len();
        println!("  -> {} trace events per run, median {:.2} ms", n_events, b.median_ns / 1e6);
        reports.push(b);
    }

    // --- Scale stress: 100 workers, 40 req/s (Fig. 10 inner loop).
    {
        let jobs = workload::poisson(40.0, 1000, &[], 4);
        let cfg = ClusterConfig::default().with_workers(100);
        let events = Simulator::simulate_ref(&cfg, &jobs).events_processed;
        let b = Bench::new("sim_1000_jobs_100_workers")
            .run(|| Simulator::simulate_ref(&cfg, &jobs))
            .with_events(events);
        println!(
            "  -> ~{:.2} M events/s ({} events per run)",
            b.events_per_sec.unwrap_or(0.0) / 1e6,
            events
        );
        reports.push(b);
    }

    // --- Execute-path batching on a same-model-heavy workload: the same
    // VPA-only stream unbatched vs coalesced (batch_max 8), so the pair of
    // events-per-sec numbers tracks the batching win over time.
    {
        let jobs = workload::poisson(4.0, 300, &[0.0, 0.0, 1.0, 0.0], 11);
        for &(batch_max, label) in
            &[(1usize, "sim_vpa_300_jobs_batch_off"), (8usize, "sim_vpa_300_jobs_batch_max8")]
        {
            let cfg = ClusterConfig::default().with_batching(batch_max, 1_000);
            let events = Simulator::simulate_ref(&cfg, &jobs).events_processed;
            let b = Bench::new(label)
                .run(|| Simulator::simulate_ref(&cfg, &jobs))
                .with_events(events);
            println!(
                "  -> ~{:.2} M events/s ({} events per run)",
                b.events_per_sec.unwrap_or(0.0) / 1e6,
                events
            );
            reports.push(b);
        }
    }

    // --- GPU cache eviction planning (queue-lookahead).
    {
        use compass::gpu::{EvictionPolicy, GpuCache};
        let mut cache =
            GpuCache::new(16_000_000_000, EvictionPolicy::QueueLookahead { window: 16 });
        cache.insert(0, 0);
        cache.insert(2, 0);
        cache.insert(1, 0);
        let lookahead: Vec<u8> = (0..32).map(|i| (i % 8) as u8).collect();
        reports.push(
            Bench::new("gpu_plan_eviction_lookahead")
                .run(|| cache.plan_eviction(5_000_000_000, &lookahead)),
        );
    }

    // --- Hash scheduler plan (baseline floor for plan cost).
    {
        let cfg = ClusterConfig::default().with_scheduler(SchedulerKind::Hash);
        let sched = sched::build(&cfg);
        let dfg = pipelines::perception(&cost);
        let r = rows(5, &mut rng);
        let speed = vec![1.0; 5];
        let job = Job { id: 9, kind: PipelineKind::Perception, arrival_us: 0, input_bytes: 1000 };
        let scratch = PlanCell::default();
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &r,
            cost: &cost,
            speed: &speed,
            scratch: &scratch,
        };
        reports.push(Bench::new("plan_hash_baseline_w5").run(|| sched.plan(&job, &dfg, &view)));
    }

    if let Some(path) = args.get_path("json") {
        bench::write_json(&path, &reports).expect("write bench json");
        println!("\n{} bench reports written to {}", reports.len(), path.display());
    }
    println!("\nall micro benches complete");
}
