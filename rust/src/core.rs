//! Shared identifiers and time base for the whole system.
//!
//! Simulated and estimated time is `u64` microseconds everywhere (the live
//! runtime converts to/from `Instant` at its edges), so scheduler estimates
//! are bit-identical between the simulator and the live coordinator.

/// Index of a worker node in the cluster (0-based, dense).
pub type WorkerId = usize;
/// Vertex id within a DFG (0-based, dense per pipeline).
pub type TaskId = usize;
/// Globally unique job-instance id.
pub type JobId = u64;
/// ML model id — bit position in the SST cache bitmap, so must stay < 64
/// (the paper's encoding; §5.2).
pub type ModelId = u8;
/// Time in microseconds.
pub type Micros = u64;

pub const MS: Micros = 1_000;
pub const SEC: Micros = 1_000_000;

pub const GB: u64 = 1_000_000_000;
pub const MB: u64 = 1_000_000;
pub const KB: u64 = 1_000;

/// FNV-1a — stable hash for the Hash scheduler baseline and object placement
/// (std's SipHash is randomly keyed per process; experiments must replay).
#[inline]
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Hash an (u64, u64) pair — the common "job id + task id" case.
#[inline]
pub fn hash_pair(a: u64, b: u64) -> u64 {
    let mut buf = [0u8; 16];
    buf[..8].copy_from_slice(&a.to_le_bytes());
    buf[8..].copy_from_slice(&b.to_le_bytes());
    fnv1a(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_stable_known_vector() {
        // FNV-1a of empty input is the offset basis.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        // And it is deterministic across calls.
        assert_eq!(fnv1a(b"compass"), fnv1a(b"compass"));
        assert_ne!(fnv1a(b"compass"), fnv1a(b"compasS"));
    }

    #[test]
    fn hash_pair_order_sensitive() {
        assert_ne!(hash_pair(1, 2), hash_pair(2, 1));
    }
}
