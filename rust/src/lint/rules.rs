//! Rule matchers for `compass-lint`. Each rule walks the token stream of
//! one file (or, for L4, cross-references several files) and appends
//! [`Finding`]s. Scoping, `#[cfg(test)]` exemption, fences, and waivers
//! are resolved here; tokenization lives in [`super::scan`].

use super::scan::{in_ranges, Directive, Scanned, Tok};

/// The rule catalog. Codes match DESIGN.md §8.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// L1: determinism — no wall clocks / order-dependent maps in
    /// `sim/`, `sched/`, `exp/`, `obs/`.
    Determinism,
    /// L2: no allocation inside `// lint: hot-path` fences.
    HotPathAlloc,
    /// L3: no `unwrap`/`expect` on channel/lock results in `coordinator/`
    /// or `fault/`.
    PanicHygiene,
    /// L4: every `obs::TraceEvent` variant handled by both exporters.
    ExporterExhaustive,
    /// L5: float comparisons go through the canonical tie-break helper.
    FloatOrdering,
}

impl Rule {
    pub fn code(self) -> &'static str {
        match self {
            Rule::Determinism => "L1",
            Rule::HotPathAlloc => "L2",
            Rule::PanicHygiene => "L3",
            Rule::ExporterExhaustive => "L4",
            Rule::FloatOrdering => "L5",
        }
    }
}

/// One lint finding, pointing at a source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: Rule,
    pub message: String,
}

/// Per-file context shared by the rule matchers.
pub struct FileCtx<'a> {
    pub path: &'a str,
    pub scanned: &'a Scanned,
    /// `#[cfg(test)]` line ranges — findings inside are dropped.
    pub tests: Vec<(u32, u32)>,
    /// `// lint: hot-path` .. `// lint: end-hot-path` line ranges.
    pub fences: Vec<(u32, u32)>,
}

impl<'a> FileCtx<'a> {
    pub fn new(path: &'a str, scanned: &'a Scanned, findings: &mut Vec<Finding>) -> FileCtx<'a> {
        let tests = super::scan::test_ranges(&scanned.toks);
        let fences = fence_ranges(path, &scanned.directives, findings);
        FileCtx { path, scanned, tests, fences }
    }

    /// First path component (`sim`, `sched`, `coordinator`, ...) of the
    /// src-relative path; top-level files map to "".
    pub fn top_dir(&self) -> &str {
        match self.path.find('/') {
            Some(k) => &self.path[..k],
            None => "",
        }
    }

    fn in_tests(&self, line: u32) -> bool {
        in_ranges(&self.tests, line)
    }

    fn in_fence(&self, line: u32) -> bool {
        in_ranges(&self.fences, line)
    }

    /// A waiver directive suppresses a finding when it sits on the same
    /// line or the line immediately above.
    fn waived(&self, line: u32, waiver: &str) -> bool {
        self.scanned
            .directives
            .iter()
            .any(|d| d.text == waiver && (d.line == line || d.line + 1 == line))
    }

    fn push(&self, out: &mut Vec<Finding>, line: u32, rule: Rule, msg: String) {
        out.push(Finding { file: self.path.to_string(), line, rule, message: msg });
    }
}

/// Known waiver directives; anything else after `lint:` is itself a
/// finding (typos must not silently disable enforcement).
const KNOWN_WAIVERS: [&str; 5] = ["sorted", "wall-clock", "alloc-ok", "may-panic", "total-order"];

/// Build `hot-path` fence ranges from directives, flagging unmatched or
/// unknown directives as findings.
pub fn fence_ranges(
    path: &str,
    directives: &[Directive],
    findings: &mut Vec<Finding>,
) -> Vec<(u32, u32)> {
    let mut fences = Vec::new();
    let mut open: Option<u32> = None;
    for d in directives {
        match d.text.as_str() {
            "hot-path" => {
                if let Some(start) = open {
                    findings.push(Finding {
                        file: path.to_string(),
                        line: d.line,
                        rule: Rule::HotPathAlloc,
                        message: format!(
                            "nested `lint: hot-path` (previous fence opened on line {start} is still open)"
                        ),
                    });
                } else {
                    open = Some(d.line);
                }
            }
            "end-hot-path" => match open.take() {
                Some(start) => fences.push((start, d.line)),
                None => findings.push(Finding {
                    file: path.to_string(),
                    line: d.line,
                    rule: Rule::HotPathAlloc,
                    message: "`lint: end-hot-path` without a matching `lint: hot-path`".to_string(),
                }),
            },
            other if KNOWN_WAIVERS.contains(&other) => {}
            other => findings.push(Finding {
                file: path.to_string(),
                line: d.line,
                rule: Rule::HotPathAlloc,
                message: format!("unknown lint directive `{other}`"),
            }),
        }
    }
    if let Some(start) = open {
        findings.push(Finding {
            file: path.to_string(),
            line: start,
            rule: Rule::HotPathAlloc,
            message: "`lint: hot-path` fence is never closed".to_string(),
        });
    }
    fences
}

/// L1 determinism: applies to `sim/`, `sched/`, `exp/`, `obs/`.
pub fn l1_determinism(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !matches!(ctx.top_dir(), "sim" | "sched" | "exp" | "obs") {
        return;
    }
    for t in &ctx.scanned.toks {
        if ctx.in_tests(t.line) {
            continue;
        }
        let (msg, waiver) = match t.text.as_str() {
            "Instant" | "SystemTime" => (
                format!("wall-clock source `{}` in deterministic code (waive with `// lint: wall-clock`)", t.text),
                "wall-clock",
            ),
            "thread_rng" => (
                "non-deterministic RNG `thread_rng` in deterministic code (waive with `// lint: wall-clock`)".to_string(),
                "wall-clock",
            ),
            "HashMap" | "HashSet" => (
                format!(
                    "order-dependent `{}` in deterministic code — use BTreeMap/BTreeSet or waive with `// lint: sorted`",
                    t.text
                ),
                "sorted",
            ),
            _ => continue,
        };
        if !ctx.waived(t.line, waiver) {
            ctx.push(out, t.line, Rule::Determinism, msg);
        }
    }
}

/// Method names banned after `.` inside a hot-path fence.
const L2_METHODS: [&str; 5] = ["clone", "collect", "to_vec", "to_owned", "to_string"];
/// Constructors banned as `Type::ctor` inside a hot-path fence.
const L2_TYPES: [&str; 3] = ["Vec", "String", "Box"];
const L2_CTORS: [&str; 2] = ["new", "with_capacity"];

/// L2 hot-path allocation: only looks inside fences; any file may fence.
pub fn l2_hot_path(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if ctx.fences.is_empty() {
        return;
    }
    let toks = &ctx.scanned.toks;
    for (i, t) in toks.iter().enumerate() {
        if !ctx.in_fence(t.line) || ctx.in_tests(t.line) || ctx.waived(t.line, "alloc-ok") {
            continue;
        }
        // `format!` / `vec!` macro invocations.
        if (t.is_ident("format") || t.is_ident("vec"))
            && matches!(toks.get(i + 1), Some(n) if n.is_punct("!"))
        {
            ctx.push(
                out,
                t.line,
                Rule::HotPathAlloc,
                format!("`{}!` allocates inside a hot-path fence", t.text),
            );
            continue;
        }
        // `Vec::new`, `String::with_capacity`, `Box::new`, `Vec::from`, ...
        if L2_TYPES.contains(&t.text.as_str())
            && matches!(toks.get(i + 1), Some(n) if n.is_punct(":"))
            && matches!(toks.get(i + 2), Some(n) if n.is_punct(":"))
        {
            if let Some(c) = toks.get(i + 3) {
                if L2_CTORS.contains(&c.text.as_str()) || c.is_ident("from") {
                    ctx.push(
                        out,
                        t.line,
                        Rule::HotPathAlloc,
                        format!("`{}::{}` allocates inside a hot-path fence", t.text, c.text),
                    );
                    continue;
                }
            }
        }
        // `.clone()` / `.collect()` / `.to_vec()` / ...
        if t.is_punct(".") {
            if let Some(m) = toks.get(i + 1) {
                if L2_METHODS.contains(&m.text.as_str()) {
                    ctx.push(
                        out,
                        m.line,
                        Rule::HotPathAlloc,
                        format!("`.{}()` allocates inside a hot-path fence", m.text),
                    );
                }
            }
        }
    }
}

/// Receiver methods whose `Result` must not be unwrapped on the live path.
const L3_SOURCES: [&str; 7] =
    ["lock", "try_lock", "recv", "try_recv", "recv_timeout", "send", "join"];

/// L3 panic hygiene: applies to `coordinator/` and `fault/` (fault policy
/// is consumed by the live path, so it must degrade rather than die).
pub fn l3_panic_hygiene(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !matches!(ctx.top_dir(), "coordinator" | "fault") {
        return;
    }
    let toks = &ctx.scanned.toks;
    for (i, t) in toks.iter().enumerate() {
        if !L3_SOURCES.contains(&t.text.as_str()) || t.kind != super::scan::TokKind::Ident {
            continue;
        }
        if ctx.in_tests(t.line) {
            continue;
        }
        // Require a call: `lock ( ... )`, then `.unwrap` / `.expect`.
        let Some(open) = toks.get(i + 1) else { continue };
        if !open.is_punct("(") {
            continue;
        }
        let Some(close) = match_paren(toks, i + 1) else { continue };
        let (Some(dot), Some(m)) = (toks.get(close + 1), toks.get(close + 2)) else {
            continue;
        };
        if dot.is_punct(".") && (m.is_ident("unwrap") || m.is_ident("expect")) {
            if ctx.waived(m.line, "may-panic") {
                continue;
            }
            ctx.push(
                out,
                m.line,
                Rule::PanicHygiene,
                format!(
                    "`{}().{}()` can panic the live path — handle the Err (poison/disconnect) or waive with `// lint: may-panic`",
                    t.text, m.text
                ),
            );
        }
    }
}

/// L5 float ordering: `partial_cmp(..).unwrap()`/`.expect()` anywhere in
/// src/ must go through the canonical `util::stats::cmp_f64` instead.
pub fn l5_float_ordering(ctx: &FileCtx, out: &mut Vec<Finding>) {
    let toks = &ctx.scanned.toks;
    for (i, t) in toks.iter().enumerate() {
        if !t.is_ident("partial_cmp") || ctx.in_tests(t.line) {
            continue;
        }
        let Some(open) = toks.get(i + 1) else { continue };
        if !open.is_punct("(") {
            continue;
        }
        let Some(close) = match_paren(toks, i + 1) else { continue };
        let (Some(dot), Some(m)) = (toks.get(close + 1), toks.get(close + 2)) else {
            continue;
        };
        if dot.is_punct(".") && (m.is_ident("unwrap") || m.is_ident("expect")) {
            if ctx.waived(m.line, "total-order") {
                continue;
            }
            ctx.push(
                out,
                m.line,
                Rule::FloatOrdering,
                "raw `partial_cmp().unwrap()` — use `util::stats::cmp_f64` (total order) or waive with `// lint: total-order`"
                    .to_string(),
            );
        }
    }
}

/// Index of the `)` matching the `(` at `open`, skipping nested parens.
fn match_paren(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct("(") {
            depth += 1;
        } else if t.is_punct(")") {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// L4 exporter exhaustiveness: every variant of `enum TraceEvent` in
/// `obs/mod.rs` must be named (as `TraceEvent :: Variant`) in both
/// `obs/chrome.rs` and `obs/prom.rs`.
pub fn l4_exporters(files: &[(String, Scanned)], out: &mut Vec<Finding>) {
    let Some((_, enum_file)) = files.iter().find(|(p, _)| p == "obs/mod.rs") else {
        return;
    };
    let variants = enum_variants(&enum_file.toks, "TraceEvent");
    if variants.is_empty() {
        return;
    }
    for exporter in ["obs/chrome.rs", "obs/prom.rs"] {
        let Some((_, sc)) = files.iter().find(|(p, _)| p == exporter) else {
            continue;
        };
        for (v, line) in &variants {
            if !mentions_variant(&sc.toks, "TraceEvent", v) {
                out.push(Finding {
                    file: exporter.to_string(),
                    line: *line,
                    rule: Rule::ExporterExhaustive,
                    message: format!(
                        "TraceEvent::{v} (obs/mod.rs:{line}) is not handled by {exporter}"
                    ),
                });
            }
        }
    }
}

/// Variant names (with declaration lines) of `enum <name> { ... }`.
pub fn enum_variants(toks: &[Tok], name: &str) -> Vec<(String, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if toks[i].is_ident("enum") && toks[i + 1].is_ident(name) && toks[i + 2].is_punct("{") {
            let mut depth = 0usize;
            let mut expect_variant = false;
            let mut j = i + 2;
            while j < toks.len() {
                let t = &toks[j];
                if t.is_punct("{") || t.is_punct("(") || t.is_punct("[") {
                    depth += 1;
                    if depth == 1 {
                        expect_variant = true;
                    }
                } else if t.is_punct("}") || t.is_punct(")") || t.is_punct("]") {
                    depth = depth.saturating_sub(1);
                    if depth == 0 && t.is_punct("}") {
                        return out;
                    }
                } else if depth == 1 {
                    if t.is_punct(",") {
                        expect_variant = true;
                    } else if t.is_punct("#") {
                        // attribute on a variant; brackets bump depth past 1
                    } else if expect_variant && t.kind == super::scan::TokKind::Ident {
                        out.push((t.text.clone(), t.line));
                        expect_variant = false;
                    }
                }
                j += 1;
            }
            return out;
        }
        i += 1;
    }
    out
}

/// True when the token stream contains `<enum> :: <variant>`.
fn mentions_variant(toks: &[Tok], enum_name: &str, variant: &str) -> bool {
    toks.windows(4).any(|w| {
        w[0].is_ident(enum_name)
            && w[1].is_punct(":")
            && w[2].is_punct(":")
            && w[3].is_ident(variant)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scan::scan;

    fn run_file(path: &str, src: &str) -> Vec<Finding> {
        let scanned = scan(src);
        let mut out = Vec::new();
        let ctx = FileCtx::new(path, &scanned, &mut out);
        l1_determinism(&ctx, &mut out);
        l2_hot_path(&ctx, &mut out);
        l3_panic_hygiene(&ctx, &mut out);
        l5_float_ordering(&ctx, &mut out);
        out
    }

    #[test]
    fn l1_scope_is_enforced() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(run_file("sim/a.rs", src).len(), 1);
        assert_eq!(run_file("util/a.rs", src).len(), 0);
    }

    #[test]
    fn l1_waiver_suppresses() {
        let src = "// lint: sorted\nuse std::collections::HashMap;\n";
        assert!(run_file("obs/a.rs", src).is_empty());
    }

    #[test]
    fn l2_fires_only_in_fences() {
        let src = "fn a() { let v = Vec::new(); }\n// lint: hot-path\nfn b() { let v = Vec::new(); }\n// lint: end-hot-path\n";
        let f = run_file("sched/a.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 3);
        assert_eq!(f[0].rule, Rule::HotPathAlloc);
    }

    #[test]
    fn l2_unclosed_fence_is_a_finding() {
        let f = run_file("sim/a.rs", "// lint: hot-path\nfn a() {}\n");
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("never closed"));
    }

    #[test]
    fn l3_requires_call_then_unwrap() {
        let src = "fn a(m: &std::sync::Mutex<u32>) { let g = m.lock().unwrap(); drop(g); }\n";
        let f = run_file("coordinator/a.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::PanicHygiene);
        // `fault/` is in scope too; elsewhere is not.
        assert_eq!(run_file("fault/a.rs", src).len(), 1);
        assert!(run_file("util/a.rs", src).is_empty());
        // `match m.lock() { .. }` is fine.
        let ok = "fn a(m: &std::sync::Mutex<u32>) { match m.lock() { Ok(_) => {} Err(_) => {} } }\n";
        assert!(run_file("coordinator/a.rs", ok).is_empty());
    }

    #[test]
    fn l5_ignores_trait_impls() {
        let src = "impl PartialOrd for S { fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> { Some(std::cmp::Ordering::Equal) } }\n";
        assert!(run_file("coordinator/a.rs", src).is_empty());
        let bad = "fn s(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        assert_eq!(run_file("util/a.rs", bad).len(), 1);
    }

    #[test]
    fn l4_flags_missing_variant() {
        let enum_src = "pub enum TraceEvent { A { x: u32 }, B(u64), C, }\n";
        let chrome = "fn f(e: &TraceEvent) { match e { TraceEvent::A { .. } => {} TraceEvent::B(_) => {} TraceEvent::C => {} } }\n";
        let prom = "fn f(e: &TraceEvent) { if let TraceEvent::A { .. } = e {} }\n";
        let files = vec![
            ("obs/mod.rs".to_string(), scan(enum_src)),
            ("obs/chrome.rs".to_string(), scan(chrome)),
            ("obs/prom.rs".to_string(), scan(prom)),
        ];
        let mut out = Vec::new();
        l4_exporters(&files, &mut out);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|f| f.file == "obs/prom.rs"));
        assert!(out.iter().any(|f| f.message.contains("TraceEvent::B")));
        assert!(out.iter().any(|f| f.message.contains("TraceEvent::C")));
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n  use std::collections::HashMap;\n  fn t(m: &std::sync::Mutex<u32>) { let _ = m.lock().unwrap(); }\n}\n";
        assert!(run_file("sim/a.rs", src).is_empty());
        assert!(run_file("coordinator/a.rs", src).is_empty());
    }
}
