//! `compass-lint`: a self-hosted, std-only static analysis pass over the
//! crate's own sources. It enforces the repo invariants every headline
//! result depends on (DESIGN.md §8): simulator determinism, hot-path
//! allocation freedom, live-path panic hygiene, exporter exhaustiveness,
//! and total-order float comparison. Run it with `compass lint`; CI runs
//! it as a required gate.
//!
//! | code | rule                  | scope                         |
//! |------|-----------------------|-------------------------------|
//! | L1   | determinism           | `sim/ sched/ exp/ obs/`       |
//! | L2   | hot-path allocation   | `// lint: hot-path` fences    |
//! | L3   | panic hygiene         | `coordinator/`, `fault/`      |
//! | L4   | exporter exhaustive   | `obs/mod.rs` vs exporters     |
//! | L5   | float ordering        | all of `src/`                 |
//!
//! The engine is two layers: [`scan`] tokenizes (skipping comments,
//! strings, and `#[cfg(test)]` regions, capturing `// lint:` directives)
//! and [`rules`] matches token patterns per rule. Everything operates on
//! `(path, source)` pairs, so fixture tests can lint virtual files.

pub mod rules;
pub mod scan;

pub use rules::{Finding, Rule};

use std::path::{Path, PathBuf};

/// Result of linting a tree (or a set of virtual files).
#[derive(Debug)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable findings, one `file:line [Lx] message` per line,
    /// plus a summary tail.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            s.push_str(&format!("{}:{} [{}] {}\n", f.file, f.line, f.rule.code(), f.message));
        }
        s.push_str(&format!(
            "compass-lint: {} finding(s) across {} file(s)\n",
            self.findings.len(),
            self.files_scanned
        ));
        s
    }

    /// Machine-readable JSON report (same shape the CI gate archives).
    pub fn to_json(&self) -> String {
        use crate::util::json::escape;
        let mut s = String::from("{\n  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
                escape(&f.file),
                f.line,
                f.rule.code(),
                escape(&f.message)
            ));
        }
        if !self.findings.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str(&format!(
            "],\n  \"files_scanned\": {},\n  \"clean\": {}\n}}\n",
            self.files_scanned,
            self.clean()
        ));
        s
    }
}

/// Lint a set of `(src-relative path, source)` pairs. Paths use `/`
/// separators and are relative to `src/` (e.g. `sim/queue.rs`), which is
/// what scopes the per-directory rules. This is the engine entry point
/// the fixture tests drive directly.
pub fn lint_sources(files: &[(String, String)]) -> Vec<Finding> {
    let scanned: Vec<(String, scan::Scanned)> =
        files.iter().map(|(p, src)| (p.clone(), scan::scan(src))).collect();
    let mut findings = Vec::new();
    for (path, sc) in &scanned {
        let ctx = rules::FileCtx::new(path, sc, &mut findings);
        rules::l1_determinism(&ctx, &mut findings);
        rules::l2_hot_path(&ctx, &mut findings);
        rules::l3_panic_hygiene(&ctx, &mut findings);
        rules::l5_float_ordering(&ctx, &mut findings);
    }
    rules::l4_exporters(&scanned, &mut findings);
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    findings.dedup();
    findings
}

/// Lint every `.rs` file under `root` (normally the crate's `src/`).
pub fn lint_tree(root: &Path) -> anyhow::Result<Report> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let src = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", p.display()))?;
        files.push((rel, src));
    }
    let findings = lint_sources(&files);
    Ok(Report { findings, files_scanned: files.len() })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| anyhow::anyhow!("read_dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| anyhow::anyhow!("read_dir {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension() == Some(std::ffi::OsStr::new("rs")) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn findings_are_sorted_and_deduped() {
        let files = vec![(
            "sim/a.rs".to_string(),
            "use std::collections::{HashMap, HashMap};\nuse std::collections::HashSet;\n"
                .to_string(),
        )];
        let f = lint_sources(&files);
        // Two HashMap mentions on line 1 dedup to one finding; HashSet on
        // line 2 stays.
        assert_eq!(f.len(), 2);
        assert_eq!(f[0].line, 1);
        assert_eq!(f[1].line, 2);
    }

    #[test]
    fn report_renders_and_serializes() {
        let files =
            vec![("obs/a.rs".to_string(), "use std::collections::HashMap;\n".to_string())];
        let findings = lint_sources(&files);
        let rep = Report { findings, files_scanned: 1 };
        let text = rep.render();
        assert!(text.contains("obs/a.rs:1 [L1]"));
        assert!(text.contains("1 finding(s) across 1 file(s)"));
        let json = rep.to_json();
        assert!(json.contains("\"rule\": \"L1\""));
        assert!(json.contains("\"clean\": false"));
    }

    #[test]
    fn empty_report_is_clean_json() {
        let rep = Report { findings: Vec::new(), files_scanned: 3 };
        assert!(rep.clean());
        assert!(rep.to_json().contains("\"clean\": true"));
    }
}
