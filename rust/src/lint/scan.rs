//! Token-level scanner for `compass-lint`.
//!
//! A deliberately small lexer: it understands exactly enough Rust surface
//! syntax to walk a source file as a stream of identifier and punctuation
//! tokens while *skipping* the places where rule trigger words are
//! meaningless — comments, string literals (normal, raw, byte), char
//! literals, and numeric literals. Line comments are inspected before
//! being discarded so `// lint: ...` directives (fences and waivers) are
//! captured with their line numbers.
//!
//! The scanner is std-only and makes no attempt at full fidelity; the
//! rules in [`super::rules`] operate on whole-identifier matches, so the
//! only hard requirements are (a) never split an identifier, and (b) never
//! emit tokens from skipped regions.

/// Kind of a lexed token. Only the two classes the rules consume.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
}

/// One token: its kind, text, and 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, s: &str) -> bool {
        self.kind == TokKind::Punct && self.text == s
    }
}

/// A `// lint: <text>` directive captured from a line comment.
#[derive(Debug, Clone)]
pub struct Directive {
    pub line: u32,
    pub text: String,
}

/// Result of scanning one source file.
#[derive(Debug, Default)]
pub struct Scanned {
    pub toks: Vec<Tok>,
    pub directives: Vec<Directive>,
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Scan `src` into tokens and directives. Operates on bytes; non-ASCII
/// bytes can only occur inside comments/strings in this crate and are
/// passed over as punctuation-free filler.
pub fn scan(src: &str) -> Scanned {
    let b = src.as_bytes();
    let mut out = Scanned::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                let start = i;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                capture_directive(&src[start..i], line, &mut out.directives);
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                // Block comment; Rust block comments nest.
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        if b[i] == b'\n' {
                            line += 1;
                        }
                        i += 1;
                    }
                }
            }
            b'"' => {
                i = skip_string(b, i, &mut line);
            }
            b'\'' => {
                i = skip_char_or_lifetime(b, i, line, &mut out.toks);
            }
            _ if is_ident_start(c) => {
                let start = i;
                while i < b.len() && is_ident_cont(b[i]) {
                    i += 1;
                }
                let word = &src[start..i];
                // Raw strings / byte strings / raw identifiers share the
                // ident-then-sigil shape: r"..", r#".."#, b"..", br#".."#,
                // b'x', r#keyword.
                if let Some(next) = raw_or_byte_start(b, i, word) {
                    match next {
                        RawNext::Str(j) => {
                            i = skip_raw_string(b, j, &mut line);
                            continue;
                        }
                        RawNext::PlainStr(j) => {
                            i = skip_string(b, j, &mut line);
                            continue;
                        }
                        RawNext::Char(j) => {
                            i = skip_char(b, j, &mut line);
                            continue;
                        }
                        RawNext::RawIdent(j) => {
                            let start2 = j;
                            let mut k = j;
                            while k < b.len() && is_ident_cont(b[k]) {
                                k += 1;
                            }
                            out.toks.push(Tok {
                                kind: TokKind::Ident,
                                text: src[start2..k].to_string(),
                                line,
                            });
                            i = k;
                            continue;
                        }
                    }
                }
                out.toks.push(Tok { kind: TokKind::Ident, text: word.to_string(), line });
            }
            _ if c.is_ascii_digit() => {
                // Numeric literal: consume digits plus any literal suffix /
                // exponent / underscores without emitting tokens. The `.`
                // of a float is folded in only when followed by a digit so
                // `1.clone()` (not valid Rust anyway) would not eat the dot.
                i += 1;
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric()
                        || b[i] == b'_'
                        || (b[i] == b'.' && i + 1 < b.len() && b[i + 1].is_ascii_digit()))
                {
                    i += 1;
                }
                // Trailing `.` of `1.` style floats.
                if i < b.len() && b[i] == b'.' && (i + 1 >= b.len() || !is_ident_start(b[i + 1])) {
                    i += 1;
                }
            }
            b' ' | b'\t' | b'\r' => {
                i += 1;
            }
            _ => {
                out.toks.push(Tok {
                    kind: TokKind::Punct,
                    text: (c as char).to_string(),
                    line,
                });
                i += 1;
            }
        }
    }
    out
}

/// What follows an identifier that might prefix a literal.
enum RawNext {
    /// Raw string starts: position of the first `#` or `"`.
    Str(usize),
    /// Byte string `b"` — plain string rules apply from the quote.
    PlainStr(usize),
    /// Byte char `b'x'` — position of the quote.
    Char(usize),
    /// Raw identifier `r#name` — position of the name start.
    RawIdent(usize),
}

fn raw_or_byte_start(b: &[u8], i: usize, word: &str) -> Option<RawNext> {
    if i >= b.len() {
        return None;
    }
    match word {
        "r" | "br" => match b[i] {
            b'"' | b'#' => {
                if word == "r" && b[i] == b'#' && i + 1 < b.len() && is_ident_start(b[i + 1]) {
                    Some(RawNext::RawIdent(i + 1))
                } else {
                    Some(RawNext::Str(i))
                }
            }
            _ => None,
        },
        "b" => match b[i] {
            b'"' => Some(RawNext::PlainStr(i)),
            b'\'' => Some(RawNext::Char(i)),
            _ => None,
        },
        _ => None,
    }
}

/// Skip a normal (escaped) string literal starting at the opening quote.
/// Returns the index just past the closing quote.
fn skip_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    i += 1; // opening quote
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Skip a raw string starting at the first `#` or `"` after the `r`/`br`
/// prefix. Returns the index just past the closing delimiter.
fn skip_raw_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    let mut hashes = 0usize;
    while i < b.len() && b[i] == b'#' {
        hashes += 1;
        i += 1;
    }
    if i < b.len() && b[i] == b'"' {
        i += 1;
    }
    while i < b.len() {
        if b[i] == b'"' {
            let mut k = i + 1;
            let mut seen = 0usize;
            while k < b.len() && b[k] == b'#' && seen < hashes {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return k;
            }
        }
        if b[i] == b'\n' {
            *line += 1;
        }
        i += 1;
    }
    i
}

/// Skip a byte-char literal `b'x'` starting at the quote.
fn skip_char(b: &[u8], mut i: usize, _line: &mut u32) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'\'' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// Disambiguate `'` between a char literal and a lifetime. Char literals
/// are skipped; for lifetimes the tick is dropped and the following
/// identifier tokenizes normally on the next loop iteration (lifetimes
/// never collide with rule trigger words, so emitting them is harmless).
fn skip_char_or_lifetime(b: &[u8], i: usize, _line: u32, _toks: &mut Vec<Tok>) -> usize {
    // `'\...'` is always a char literal.
    if i + 1 < b.len() && b[i + 1] == b'\\' {
        let mut k = i + 2;
        if k < b.len() {
            k += 1; // escaped char
        }
        // Multi-char escapes (\x41, \u{..}) — scan to the closing quote.
        while k < b.len() && b[k] != b'\'' {
            k += 1;
        }
        return k + 1;
    }
    // `'x'` — one char then a closing quote.
    if i + 2 < b.len() && b[i + 2] == b'\'' {
        return i + 3;
    }
    // Lifetime: consume only the tick.
    i + 1
}

/// If `comment` is a `// lint: <text>` directive, record it.
fn capture_directive(comment: &str, line: u32, out: &mut Vec<Directive>) {
    // Strip `//`, any further `/` (doc comments) or `!` (inner doc).
    let body = comment.trim_start_matches('/').trim_start_matches('!').trim();
    if let Some(rest) = body.strip_prefix("lint:") {
        out.push(Directive { line, text: rest.trim().to_string() });
    }
}

/// Half-open line ranges `[start, end]` (inclusive) covered by
/// `#[cfg(test)]` items. Rules skip findings inside these ranges: test
/// code is allowed to use wall clocks, HashMaps, unwraps, and friends.
pub fn test_ranges(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if is_cfg_test_at(toks, i) {
            let start_line = toks[i].line;
            // Walk to the end of the annotated item: the matching close
            // brace of its first `{`, or a `;` at depth 0 for braceless
            // items (`#[cfg(test)] use ...;`).
            let mut j = i + 7; // past `# [ cfg ( test ) ]`
            let mut depth = 0usize;
            let mut end_line = start_line;
            while j < toks.len() {
                let t = &toks[j];
                if t.is_punct("{") {
                    depth += 1;
                } else if t.is_punct("}") {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        end_line = t.line;
                        j += 1;
                        break;
                    }
                } else if t.is_punct(";") && depth == 0 {
                    end_line = t.line;
                    j += 1;
                    break;
                }
                end_line = t.line;
                j += 1;
            }
            ranges.push((start_line, end_line));
            i = j;
        } else {
            i += 1;
        }
    }
    ranges
}

/// Token pattern `# [ cfg ( test ) ]` beginning at index `i`.
fn is_cfg_test_at(toks: &[Tok], i: usize) -> bool {
    i + 6 < toks.len()
        && toks[i].is_punct("#")
        && toks[i + 1].is_punct("[")
        && toks[i + 2].is_ident("cfg")
        && toks[i + 3].is_punct("(")
        && toks[i + 4].is_ident("test")
        && toks[i + 5].is_punct(")")
        && toks[i + 6].is_punct("]")
}

/// True when `line` falls inside any of `ranges`.
pub fn in_ranges(ranges: &[(u32, u32)], line: u32) -> bool {
    ranges.iter().any(|&(s, e)| s <= line && line <= e)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(s: &Scanned) -> Vec<&str> {
        s.toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect()
    }

    #[test]
    fn strings_and_comments_are_skipped() {
        let s = scan("let x = \"Instant::now()\"; // HashMap here\n/* SystemTime */ let y = 1;");
        let ids = idents(&s);
        assert_eq!(ids, vec!["let", "x", "let", "y"]);
    }

    #[test]
    fn raw_strings_are_skipped() {
        let s = scan("let x = r#\"thread_rng \"quoted\" inside\"#; let z = br\"HashSet\";");
        let ids = idents(&s);
        assert_eq!(ids, vec!["let", "x", "let", "z"]);
    }

    #[test]
    fn raw_identifiers_tokenize() {
        let s = scan("let r#type = 1;");
        assert!(s.toks.iter().any(|t| t.is_ident("type")));
    }

    #[test]
    fn char_vs_lifetime() {
        let s = scan("let c = 'x'; fn f<'a>(v: &'a str) {} let q = '\\n';");
        let ids = idents(&s);
        assert!(ids.contains(&"a")); // lifetime ident survives
        assert!(!ids.contains(&"x")); // char literal content does not
        assert!(!ids.contains(&"n"));
    }

    #[test]
    fn directives_are_captured_with_lines() {
        let s = scan("fn a() {}\n// lint: hot-path\nfn b() {}\n// lint: end-hot-path\n");
        assert_eq!(s.directives.len(), 2);
        assert_eq!(s.directives[0].line, 2);
        assert_eq!(s.directives[0].text, "hot-path");
        assert_eq!(s.directives[1].line, 4);
        assert_eq!(s.directives[1].text, "end-hot-path");
    }

    #[test]
    fn doc_comment_directives_are_captured() {
        let s = scan("/// lint: sorted\nstruct S;");
        assert_eq!(s.directives.len(), 1);
        assert_eq!(s.directives[0].text, "sorted");
    }

    #[test]
    fn cfg_test_ranges_cover_modules_and_braceless_items() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n  fn t() { let m = 1; }\n}\n#[cfg(test)]\nuse std::collections::HashMap;\nfn live2() {}\n";
        let s = scan(src);
        let r = test_ranges(&s.toks);
        assert_eq!(r.len(), 2);
        assert!(in_ranges(&r, 3));
        assert!(in_ranges(&r, 4));
        assert!(in_ranges(&r, 7));
        assert!(!in_ranges(&r, 1));
        assert!(!in_ranges(&r, 8));
    }

    #[test]
    fn line_numbers_track_through_literals() {
        let s = scan("let a = \"one\nstill the string\";\nlet b = 2;");
        let b_tok = s.toks.iter().find(|t| t.is_ident("b")).expect("b token");
        assert_eq!(b_tok.line, 3);
    }

    #[test]
    fn numbers_do_not_emit_tokens() {
        let s = scan("let x = 1_000u64 + 2.5e3 + 0xFFu8;");
        let ids = idents(&s);
        assert_eq!(ids, vec!["let", "x"]);
    }
}
