//! Prometheus text-exposition exporter: a one-shot snapshot of the
//! end-of-run metrics (and, when a trace is available, phase-latency
//! histograms reconstructed from it) in the format `promtool` and the
//! Prometheus scraper accept. Histograms use the standard cumulative
//! `_bucket{le=...}` / `_sum` / `_count` triple with `le` in seconds.

use super::{Histogram, Trace, TraceEvent};
use crate::metrics::MetricsSink;
use std::collections::BTreeMap;
use std::fmt::Write;

const US_PER_SEC: f64 = 1_000_000.0;

fn gauge(out: &mut String, name: &str, help: &str, v: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

fn counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

/// Emit a histogram of microsecond samples as a seconds-based Prometheus
/// histogram. Empty buckets are elided (cumulative counts stay correct);
/// the `+Inf` bucket always closes the series.
fn histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, &c) in h.bucket_counts().iter().enumerate() {
        if c == 0 {
            continue;
        }
        cum += c;
        if i + 1 < super::hist::N_BUCKETS {
            let le = Histogram::bucket_upper(i) as f64 / US_PER_SEC;
            let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
        }
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum {}", h.sum() as f64 / US_PER_SEC);
    let _ = writeln!(out, "{name}_count {}", h.count());
}

/// Emit a histogram of *unitless* samples (e.g. batch member counts): `le`
/// stays in the sample's own unit instead of being scaled to seconds.
fn histogram_unitless(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, &c) in h.bucket_counts().iter().enumerate() {
        if c == 0 {
            continue;
        }
        cum += c;
        if i + 1 < super::hist::N_BUCKETS {
            let le = Histogram::bucket_upper(i);
            let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
        }
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum {}", h.sum());
    let _ = writeln!(out, "{name}_count {}", h.count());
}

/// Stable `kind` label for a trace event. This match is deliberately
/// exhaustive — no `_` arm — so adding a `TraceEvent` variant without a
/// Prometheus series label is a compile error; `compass-lint` L4
/// additionally cross-checks that every variant is named here.
fn event_kind(ev: &TraceEvent) -> &'static str {
    match ev {
        TraceEvent::JobArrive { .. } => "job_arrive",
        TraceEvent::JobComplete { .. } => "job_complete",
        TraceEvent::TaskEnqueue { .. } => "task_enqueue",
        TraceEvent::ExecStart { .. } => "exec_start",
        TraceEvent::ExecEnd { .. } => "exec_end",
        TraceEvent::FetchStart { .. } => "fetch_start",
        TraceEvent::FetchEnd { .. } => "fetch_end",
        TraceEvent::Decision { .. } => "decision",
        TraceEvent::CacheHit { .. } => "cache_hit",
        TraceEvent::CacheMiss { .. } => "cache_miss",
        TraceEvent::CacheInsert { .. } => "cache_insert",
        TraceEvent::CacheEvict { .. } => "cache_evict",
        TraceEvent::SstStaleness { .. } => "sst_staleness",
        TraceEvent::BatchFormed { .. } => "batch_formed",
        TraceEvent::BatchExecuted { .. } => "batch_executed",
        TraceEvent::WorkerFailed { .. } => "worker_failed",
        TraceEvent::TaskRetried { .. } => "task_retried",
        TraceEvent::TaskRePlaced { .. } => "task_re_placed",
        TraceEvent::JobDegraded { .. } => "job_degraded",
        TraceEvent::RuntimeLoadFailed { .. } => "runtime_load_failed",
    }
}

/// Render an end-of-run metrics snapshot, optionally enriched with
/// phase-latency histograms from `trace`.
pub fn prometheus_snapshot(m: &MetricsSink, trace: Option<&Trace>) -> String {
    let mut out = String::with_capacity(4096);

    counter(
        &mut out,
        "compass_jobs_completed_total",
        "Jobs completed during the run.",
        m.jobs.len() as u64,
    );
    counter(
        &mut out,
        "compass_jobs_incomplete_total",
        "Jobs generated but not completed when the run ended.",
        m.incomplete as u64,
    );
    gauge(&mut out, "compass_span_seconds", "Observed run span.", m.span_us as f64 / US_PER_SEC);
    gauge(
        &mut out,
        "compass_gpu_utilization_percent",
        "Fraction of wall time GPUs were executing (Table 1).",
        m.gpu_utilization(),
    );
    gauge(
        &mut out,
        "compass_gpu_memory_utilization_percent",
        "Time-averaged resident model bytes over capacity (Table 1).",
        m.gpu_memory_utilization(),
    );
    gauge(
        &mut out,
        "compass_gpu_energy_joules",
        "Integrated energy under the linear T4 power model (Table 1).",
        m.gpu_energy_joules(),
    );
    gauge(
        &mut out,
        "compass_cache_hit_rate_percent",
        "GPU model-cache hit rate (Table 1).",
        m.cache_hit_rate(),
    );
    gauge(
        &mut out,
        "compass_active_workers",
        "Workers doing non-negligible work (Fig. 10).",
        m.active_workers() as f64,
    );

    // Fault-injection and recovery counters (DESIGN.md §9); all zero in a
    // failure-free run.
    counter(
        &mut out,
        "compass_workers_failed_total",
        "Workers declared dead by the staleness detector.",
        m.faults.workers_failed,
    );
    counter(
        &mut out,
        "compass_tasks_re_placed_total",
        "Orphaned tasks re-placed after a worker death.",
        m.faults.tasks_re_placed,
    );
    counter(
        &mut out,
        "compass_task_retries_total",
        "Transient-failure retries (bounded, exponential backoff).",
        m.faults.task_retries,
    );
    counter(
        &mut out,
        "compass_jobs_failed_total",
        "Jobs that reached the Failed outcome (no alive worker).",
        m.faults.jobs_failed,
    );
    counter(
        &mut out,
        "compass_jobs_degraded_total",
        "Jobs completed only after fault recovery (Degraded outcome).",
        m.degraded_jobs() as u64,
    );

    // Per-worker counters, labeled by worker id.
    let per_worker: [(&str, &str, fn(&crate::metrics::WorkerMetrics) -> u64); 4] = [
        ("compass_worker_cache_hits_total", "Model-cache hits.", |w| w.hits),
        ("compass_worker_cache_misses_total", "Model-cache misses.", |w| w.misses),
        ("compass_worker_model_fetches_total", "Model fetches started.", |w| w.fetches),
        ("compass_worker_cache_evictions_total", "Models evicted.", |w| w.evictions),
    ];
    for (name, help, get) in per_worker {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        for (i, w) in m.workers.iter().enumerate() {
            let _ = writeln!(out, "{name}{{worker=\"{i}\"}} {}", get(w));
        }
    }
    let _ = writeln!(out, "# HELP compass_worker_busy_seconds Time spent executing tasks.");
    let _ = writeln!(out, "# TYPE compass_worker_busy_seconds gauge");
    for (i, w) in m.workers.iter().enumerate() {
        let _ =
            writeln!(out, "compass_worker_busy_seconds{{worker=\"{i}\"}} {}", w.busy_us as f64 / US_PER_SEC);
    }

    // Job end-to-end latency histogram from the sink (always available).
    // Failed jobs never produced a result, so they have no latency.
    let mut job_lat = Histogram::new();
    for j in m.jobs.iter().filter(|j| !j.failed()) {
        job_lat.record(j.latency_us());
    }
    histogram(
        &mut out,
        "compass_job_latency_seconds",
        "End-to-end job latency.",
        &job_lat,
    );

    // Phase histograms need per-event data: only present with a trace.
    if let Some(tr) = trace {
        histogram(
            &mut out,
            "compass_task_queue_wait_seconds",
            "Per-task queue-wait phase (enqueue to exec start).",
            &tr.queue_wait_hist(),
        );
        histogram(
            &mut out,
            "compass_task_exec_seconds",
            "Per-task execute phase.",
            &tr.exec_hist(),
        );
        histogram(
            &mut out,
            "compass_model_fetch_seconds",
            "Model fetch (cold load) duration.",
            &tr.fetch_hist(),
        );
        histogram(
            &mut out,
            "compass_sst_staleness_seconds",
            "SST load-row staleness at decision time.",
            &tr.sst_staleness_hist(),
        );
        histogram_unitless(
            &mut out,
            "compass_batch_size",
            "Members per executed batch (1 = solo execution).",
            &tr.batch_size_hist(),
        );
        let (mut batches, mut batched_tasks) = (0u64, 0u64);
        let mut by_kind: BTreeMap<&'static str, u64> = BTreeMap::new();
        for ev in &tr.events {
            *by_kind.entry(event_kind(ev)).or_insert(0) += 1;
            if let TraceEvent::BatchExecuted { size, .. } = *ev {
                batches += 1;
                batched_tasks += size as u64;
            }
        }
        // Per-kind event counts; BTreeMap keeps label order deterministic.
        let _ = writeln!(
            out,
            "# HELP compass_trace_events_by_kind_total Trace events retained, by event kind."
        );
        let _ = writeln!(out, "# TYPE compass_trace_events_by_kind_total counter");
        for (kind, n) in &by_kind {
            let _ = writeln!(out, "compass_trace_events_by_kind_total{{kind=\"{kind}\"}} {n}");
        }
        counter(
            &mut out,
            "compass_batches_executed_total",
            "Batches retired on the execute path.",
            batches,
        );
        counter(
            &mut out,
            "compass_batched_tasks_total",
            "Tasks retired as members of executed batches.",
            batched_tasks,
        );
        counter(
            &mut out,
            "compass_trace_events_total",
            "Trace events retained in the ring buffer.",
            tr.events.len() as u64,
        );
        counter(
            &mut out,
            "compass_trace_dropped_total",
            "Oldest trace events overwritten by ring wraparound.",
            tr.dropped,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::PipelineKind;
    use crate::metrics::{JobRecord, WorkerMetrics};
    use crate::obs::TraceEvent;

    fn sink() -> MetricsSink {
        MetricsSink {
            jobs: vec![JobRecord {
                kind: PipelineKind::Vpa,
                arrival_us: 0,
                completion_us: 2_000_000,
                lower_bound_us: 1_000_000,
                outcome: crate::metrics::JobOutcome::Completed,
            }],
            workers: vec![WorkerMetrics {
                busy_us: 500_000,
                hits: 3,
                misses: 1,
                gpu_capacity: 16_000_000_000,
                active: true,
                ..Default::default()
            }],
            span_us: 10_000_000,
            incomplete: 2,
            faults: Default::default(),
        }
    }

    #[test]
    fn snapshot_contains_core_series() {
        let text = prometheus_snapshot(&sink(), None);
        assert!(text.contains("compass_jobs_completed_total 1"));
        assert!(text.contains("compass_jobs_incomplete_total 2"));
        assert!(text.contains("compass_worker_cache_hits_total{worker=\"0\"} 3"));
        assert!(text.contains("compass_job_latency_seconds_count 1"));
        assert!(text.contains("le=\"+Inf\"} 1"));
        // Every HELP has a TYPE.
        let helps = text.matches("# HELP").count();
        let types = text.matches("# TYPE").count();
        assert_eq!(helps, types);
    }

    #[test]
    fn trace_adds_phase_histograms() {
        let trace = Trace {
            events: vec![
                TraceEvent::TaskEnqueue { job: 1, task: 0, worker: 0, t: 0 },
                TraceEvent::ExecStart { job: 1, task: 0, worker: 0, t: 100 },
                TraceEvent::ExecEnd { job: 1, task: 0, worker: 0, t: 300 },
            ],
            dropped: 0,
        };
        let text = prometheus_snapshot(&sink(), Some(&trace));
        assert!(text.contains("compass_task_queue_wait_seconds_count 1"));
        assert!(text.contains("compass_task_exec_seconds_count 1"));
        assert!(text.contains("compass_trace_events_total 3"));
        assert!(text.contains("compass_trace_events_by_kind_total{kind=\"task_enqueue\"} 1"));
        assert!(text.contains("compass_trace_events_by_kind_total{kind=\"exec_start\"} 1"));
        assert!(text.contains("compass_trace_events_by_kind_total{kind=\"exec_end\"} 1"));
    }

    #[test]
    fn trace_adds_batch_series() {
        let trace = Trace {
            events: vec![
                TraceEvent::BatchFormed { worker: 0, model: 2, size: 3, t: 10 },
                TraceEvent::BatchExecuted { worker: 0, model: 2, size: 3, t: 40 },
                TraceEvent::BatchExecuted { worker: 1, model: 2, size: 1, t: 50 },
            ],
            dropped: 0,
        };
        let text = prometheus_snapshot(&sink(), Some(&trace));
        assert!(text.contains("compass_batch_size_count 2"));
        assert!(text.contains("compass_batch_size_sum 4"));
        assert!(text.contains("compass_batches_executed_total 2"));
        assert!(text.contains("compass_batched_tasks_total 4"));
        // Unitless buckets: le stays in member counts, not seconds.
        assert!(text.contains("compass_batch_size_bucket{le=\"1\"} 1"));
        let helps = text.matches("# HELP").count();
        let types = text.matches("# TYPE").count();
        assert_eq!(helps, types);
    }

    #[test]
    fn fault_counters_present_and_zero_by_default() {
        let text = prometheus_snapshot(&sink(), None);
        assert!(text.contains("compass_workers_failed_total 0"));
        assert!(text.contains("compass_tasks_re_placed_total 0"));
        assert!(text.contains("compass_task_retries_total 0"));
        assert!(text.contains("compass_jobs_failed_total 0"));
        assert!(text.contains("compass_jobs_degraded_total 0"));
        let mut s = sink();
        s.faults.workers_failed = 2;
        s.faults.tasks_re_placed = 5;
        let text = prometheus_snapshot(&s, None);
        assert!(text.contains("compass_workers_failed_total 2"));
        assert!(text.contains("compass_tasks_re_placed_total 5"));
    }

    #[test]
    fn fault_events_have_kind_labels() {
        let trace = Trace {
            events: vec![
                TraceEvent::WorkerFailed { worker: 1, detector: 0, t: 5 },
                TraceEvent::TaskRetried { worker: 0, model: 2, attempt: 0, t: 6 },
                TraceEvent::TaskRePlaced { job: 3, task: 1, from: 1, to: 0, t: 7 },
                TraceEvent::JobDegraded { job: 3, kind: PipelineKind::Vpa, t: 9 },
            ],
            dropped: 0,
        };
        let text = prometheus_snapshot(&sink(), Some(&trace));
        assert!(text.contains("compass_trace_events_by_kind_total{kind=\"worker_failed\"} 1"));
        assert!(text.contains("compass_trace_events_by_kind_total{kind=\"task_retried\"} 1"));
        assert!(text.contains("compass_trace_events_by_kind_total{kind=\"task_re_placed\"} 1"));
        assert!(text.contains("compass_trace_events_by_kind_total{kind=\"job_degraded\"} 1"));
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let mut h = Histogram::new();
        h.record(1); // bucket 1 (le 1µs)
        h.record(1000); // bucket 10 (le 1023µs)
        let mut out = String::new();
        histogram(&mut out, "x_seconds", "test.", &h);
        assert!(out.contains("x_seconds_bucket{le=\"0.000001\"} 1"));
        assert!(out.contains("x_seconds_bucket{le=\"0.001023\"} 2"));
        assert!(out.contains("x_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(out.contains("x_seconds_count 2"));
    }
}
