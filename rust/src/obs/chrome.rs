//! Chrome `trace_event` JSON exporter.
//!
//! Emits the JSON-object flavor of the trace-event format: a `traceEvents`
//! array plus `displayTimeUnit`. Loadable in Perfetto (ui.perfetto.dev) or
//! `chrome://tracing`. Layout: pid 0 is the cluster; each worker is one
//! thread (tid = worker id) carrying its queue/fetch/exec spans as complete
//! (`ph:"X"`) duration events; tid [`JOBS_TID`] is a synthetic "jobs" track
//! with job arrive/complete instants; scheduler decisions are instant events
//! on the deciding worker's track with candidate scores in `args`.

use super::{Trace, TraceEvent};
use crate::util::json::escape;
use std::collections::BTreeSet;
use std::fmt::Write;

/// Synthetic tid for the job-lifecycle track (above any real worker id).
pub const JOBS_TID: u32 = 65_535;

fn instant(out: &mut String, name: &str, cat: &str, tid: u32, t: u64, args: &str) {
    let _ = writeln!(
        out,
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":0,\"tid\":{},\"ts\":{},\"args\":{{{}}}}},",
        escape(name),
        cat,
        tid,
        t,
        args
    );
}

fn span(out: &mut String, name: &str, cat: &str, tid: u32, ts: u64, dur: u64, args: &str) {
    let _ = writeln!(
        out,
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{},\"dur\":{},\"args\":{{{}}}}},",
        escape(name),
        cat,
        tid,
        ts,
        dur,
        args
    );
}

/// Render `trace` as a Chrome trace_event JSON document.
pub fn chrome_trace(trace: &Trace) -> String {
    let mut out = String::with_capacity(256 + trace.events.len() * 160);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");

    // Thread-name metadata: one track per worker seen anywhere in the trace,
    // plus the synthetic jobs track.
    let mut workers: BTreeSet<u16> = BTreeSet::new();
    for ev in &trace.events {
        match *ev {
            TraceEvent::TaskEnqueue { worker, .. }
            | TraceEvent::ExecStart { worker, .. }
            | TraceEvent::ExecEnd { worker, .. }
            | TraceEvent::FetchStart { worker, .. }
            | TraceEvent::FetchEnd { worker, .. }
            | TraceEvent::CacheHit { worker, .. }
            | TraceEvent::CacheMiss { worker, .. }
            | TraceEvent::CacheInsert { worker, .. }
            | TraceEvent::CacheEvict { worker, .. }
            | TraceEvent::SstStaleness { worker, .. }
            | TraceEvent::BatchFormed { worker, .. }
            | TraceEvent::BatchExecuted { worker, .. }
            | TraceEvent::TaskRetried { worker, .. }
            | TraceEvent::RuntimeLoadFailed { worker, .. } => {
                workers.insert(worker);
            }
            TraceEvent::Decision { decider, chosen, .. } => {
                workers.insert(decider);
                workers.insert(chosen);
            }
            TraceEvent::WorkerFailed { worker, detector, .. } => {
                workers.insert(worker);
                workers.insert(detector);
            }
            TraceEvent::TaskRePlaced { from, to, .. } => {
                workers.insert(from);
                workers.insert(to);
            }
            // Degraded-job instants live on the synthetic jobs track.
            TraceEvent::JobDegraded { .. } => {}
            // Job lifecycle events live on the synthetic jobs track, not a
            // worker track. Exhaustive by design (lint rule L4).
            TraceEvent::JobArrive { .. } | TraceEvent::JobComplete { .. } => {}
        }
    }
    let _ = writeln!(
        out,
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\"args\":{{\"name\":\"compass cluster\"}}}},"
    );
    for &w in &workers {
        let _ = writeln!(
            out,
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{w},\"args\":{{\"name\":\"worker {w}\"}}}},"
        );
    }
    let _ = writeln!(
        out,
        "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{JOBS_TID},\"args\":{{\"name\":\"jobs\"}}}},"
    );

    // Per-task spans: queue-wait then execute, on the executing worker's
    // track; model fetches as their own spans.
    for s in trace.task_spans() {
        let args = format!("\"job\":{},\"task\":{}", s.job, s.task);
        let qname = format!("queue j{}:t{}", s.job, s.task);
        span(&mut out, &qname, "queue", s.worker as u32, s.enqueue_us, s.queue_wait_us(), &args);
        let xname = format!("exec j{}:t{}", s.job, s.task);
        span(&mut out, &xname, "exec", s.worker as u32, s.start_us, s.exec_us(), &args);
    }
    for f in trace.fetch_spans() {
        let args = format!("\"model\":{}", f.model);
        let name = format!("fetch m{}", f.model);
        span(
            &mut out,
            &name,
            "fetch",
            f.worker as u32,
            f.start_us,
            f.end_us.saturating_sub(f.start_us),
            &args,
        );
    }

    for ev in &trace.events {
        match *ev {
            TraceEvent::JobArrive { job, kind, t } => {
                let args = format!("\"job\":{},\"kind\":\"{}\"", job, kind.name());
                instant(&mut out, "job arrive", "job", JOBS_TID, t, &args);
            }
            TraceEvent::JobComplete { job, kind, latency_us, t } => {
                let args = format!(
                    "\"job\":{},\"kind\":\"{}\",\"latency_us\":{}",
                    job,
                    kind.name(),
                    latency_us
                );
                instant(&mut out, "job complete", "job", JOBS_TID, t, &args);
            }
            TraceEvent::Decision { job, task, phase, decider, chosen, candidates, t } => {
                let mut cands = String::new();
                for (i, (w, score)) in candidates.iter().enumerate() {
                    if i > 0 {
                        cands.push(',');
                    }
                    let _ = write!(cands, "{{\"w\":{w},\"score_us\":{score}}}");
                }
                let args = format!(
                    "\"job\":{job},\"task\":{task},\"phase\":\"{}\",\"chosen\":{chosen},\"scored\":{},\"candidates\":[{cands}]",
                    phase.name(),
                    candidates.total
                );
                let name = format!("decision {} j{}:t{}", phase.name(), job, task);
                instant(&mut out, &name, "sched", decider as u32, t, &args);
            }
            TraceEvent::CacheHit { worker, model, free_bytes, t } => {
                let args = format!("\"model\":{model},\"free_bytes\":{free_bytes}");
                instant(&mut out, "cache hit", "cache", worker as u32, t, &args);
            }
            TraceEvent::CacheMiss { worker, model, free_bytes, t } => {
                let args = format!("\"model\":{model},\"free_bytes\":{free_bytes}");
                instant(&mut out, "cache miss", "cache", worker as u32, t, &args);
            }
            TraceEvent::CacheInsert { worker, model, free_bytes, t } => {
                let args = format!("\"model\":{model},\"free_bytes\":{free_bytes}");
                instant(&mut out, "cache insert", "cache", worker as u32, t, &args);
            }
            TraceEvent::CacheEvict { worker, model, free_bytes, t } => {
                let args = format!("\"model\":{model},\"free_bytes\":{free_bytes}");
                instant(&mut out, "cache evict", "cache", worker as u32, t, &args);
            }
            TraceEvent::SstStaleness { worker, load_staleness_us, cache_staleness_us, t } => {
                let args = format!(
                    "\"load_staleness_us\":{load_staleness_us},\"cache_staleness_us\":{cache_staleness_us}"
                );
                instant(&mut out, "sst staleness", "sst", worker as u32, t, &args);
            }
            TraceEvent::BatchFormed { worker, model, size, t } => {
                let args = format!("\"model\":{model},\"size\":{size}");
                let name = format!("batch formed m{model} x{size}");
                instant(&mut out, &name, "batch", worker as u32, t, &args);
            }
            TraceEvent::BatchExecuted { worker, model, size, t } => {
                let args = format!("\"model\":{model},\"size\":{size}");
                let name = format!("batch executed m{model} x{size}");
                instant(&mut out, &name, "batch", worker as u32, t, &args);
            }
            TraceEvent::WorkerFailed { worker, detector, t } => {
                let args = format!("\"worker\":{worker},\"detector\":{detector}");
                let name = format!("worker {worker} failed");
                // Rendered on the dead worker's own track, where its spans
                // visibly stop.
                instant(&mut out, &name, "fault", worker as u32, t, &args);
            }
            TraceEvent::TaskRetried { worker, model, attempt, t } => {
                let args = format!("\"model\":{model},\"attempt\":{attempt}");
                let name = format!("retry m{model} #{attempt}");
                instant(&mut out, &name, "fault", worker as u32, t, &args);
            }
            TraceEvent::TaskRePlaced { job, task, from, to, t } => {
                let args = format!("\"job\":{job},\"task\":{task},\"from\":{from},\"to\":{to}");
                let name = format!("re-place j{job}:t{task} w{from}->w{to}");
                instant(&mut out, &name, "fault", to as u32, t, &args);
            }
            TraceEvent::JobDegraded { job, kind, t } => {
                let args = format!("\"job\":{},\"kind\":\"{}\"", job, kind.name());
                instant(&mut out, "job degraded", "fault", JOBS_TID, t, &args);
            }
            TraceEvent::RuntimeLoadFailed { worker, attempt, t } => {
                let args = format!("\"worker\":{worker},\"attempt\":{attempt}");
                let name = format!("pjrt load failed #{attempt}");
                instant(&mut out, &name, "fault", worker as u32, t, &args);
            }
            // Task/fetch edge events are rendered as reconstructed duration
            // spans above (task_spans / fetch_spans), not as instants.
            // Exhaustive by design (lint rule L4).
            TraceEvent::TaskEnqueue { .. }
            | TraceEvent::ExecStart { .. }
            | TraceEvent::ExecEnd { .. }
            | TraceEvent::FetchStart { .. }
            | TraceEvent::FetchEnd { .. } => {}
        }
    }

    // Trailer metadata doubles as the "no trailing comma" terminator.
    let _ = writeln!(
        out,
        "{{\"name\":\"trace_dropped_events\",\"ph\":\"M\",\"pid\":0,\"args\":{{\"dropped\":{}}}}}",
        trace.dropped
    );
    out.push_str("]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{CandidateSet, SchedPhase};
    use crate::util::json::Json;

    fn sample_trace() -> Trace {
        let mut cands = CandidateSet::default();
        cands.push(0, 500);
        cands.push(1, 300);
        Trace {
            events: vec![
                TraceEvent::JobArrive { job: 7, kind: crate::dfg::PipelineKind::Vpa, t: 0 },
                TraceEvent::Decision {
                    job: 7,
                    task: 1,
                    phase: SchedPhase::Plan,
                    decider: 0,
                    chosen: 1,
                    candidates: cands,
                    t: 1,
                },
                TraceEvent::TaskEnqueue { job: 7, task: 1, worker: 1, t: 2 },
                TraceEvent::FetchStart { worker: 1, model: 4, t: 2 },
                TraceEvent::FetchEnd { worker: 1, model: 4, t: 40 },
                TraceEvent::ExecStart { job: 7, task: 1, worker: 1, t: 40 },
                TraceEvent::ExecEnd { job: 7, task: 1, worker: 1, t: 90 },
                TraceEvent::JobComplete {
                    job: 7,
                    kind: crate::dfg::PipelineKind::Vpa,
                    latency_us: 90,
                    t: 90,
                },
            ],
            dropped: 0,
        }
    }

    #[test]
    fn output_is_valid_json_with_expected_shape() {
        let text = chrome_trace(&sample_trace());
        let json = Json::parse(&text).expect("chrome trace must parse");
        let events = json.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
        // Phase spans: queue + exec + fetch all present as ph:"X".
        let cats: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .filter_map(|e| e.get("cat").and_then(|c| c.as_str()))
            .collect();
        assert!(cats.contains(&"queue"));
        assert!(cats.contains(&"exec"));
        assert!(cats.contains(&"fetch"));
        // The decision instant carries candidate scores.
        let decision = events
            .iter()
            .find(|e| e.get("cat").and_then(|c| c.as_str()) == Some("sched"))
            .expect("decision event");
        let cands = decision
            .get("args")
            .and_then(|a| a.get("candidates"))
            .and_then(|c| c.as_arr())
            .expect("candidates array");
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[1].get("score_us").and_then(|s| s.as_u64()), Some(300));
        assert_eq!(decision.get("args").and_then(|a| a.get("chosen")).and_then(|c| c.as_u64()), Some(1));
        // Worker track metadata exists.
        assert!(events.iter().any(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("M")
                && e.get("args").and_then(|a| a.get("name")).and_then(|n| n.as_str())
                    == Some("worker 1")
        }));
    }

    #[test]
    fn span_durations_match_phases() {
        let text = chrome_trace(&sample_trace());
        let json = Json::parse(&text).unwrap();
        let events = json.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let dur_of = |cat: &str| {
            events
                .iter()
                .find(|e| e.get("cat").and_then(|c| c.as_str()) == Some(cat))
                .and_then(|e| e.get("dur"))
                .and_then(|d| d.as_u64())
                .unwrap()
        };
        assert_eq!(dur_of("queue"), 38);
        assert_eq!(dur_of("exec"), 50);
        assert_eq!(dur_of("fetch"), 38);
    }
}
