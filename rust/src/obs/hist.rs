//! Log-bucketed latency histogram: fixed footprint, O(1) record, and
//! p50/p90/p99 accessors good to a factor of 2 (bucket i holds values whose
//! bit-length is i, i.e. [2^(i-1), 2^i - 1] µs). Percentiles are bucket
//! midpoints clamped to the observed [min, max], so constant-valued streams
//! report the exact value.

/// Number of power-of-two buckets. Bucket 0 holds the value 0; buckets
/// 1..N-1 hold values of that bit-length; the last bucket absorbs everything
/// ≥ 2^(N-2) (~9.1 minutes in µs — far beyond any job latency here).
pub const N_BUCKETS: usize = 41;

#[derive(Debug, Clone)]
pub struct Histogram {
    counts: [u64; N_BUCKETS],
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: [0; N_BUCKETS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    #[inline]
    fn bucket_of(v: u64) -> usize {
        if v == 0 {
            return 0;
        }
        let bits = 64 - v.leading_zeros() as usize;
        bits.min(N_BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` (the last bucket is unbounded).
    pub fn bucket_upper(i: usize) -> u64 {
        match i {
            0 => 0,
            _ if i >= N_BUCKETS - 1 => u64::MAX,
            _ => (1u64 << i) - 1,
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> u128 {
        self.sum
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Raw per-bucket counts (for exporters).
    pub fn bucket_counts(&self) -> &[u64; N_BUCKETS] {
        &self.counts
    }

    /// Value at quantile `p` in [0, 1]: midpoint of the bucket containing
    /// the p-th ranked sample, clamped to the observed range.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                let lo = if i == 0 { 0 } else { 1u64 << (i - 1) };
                let hi = Self::bucket_upper(i);
                let mid = lo + (hi - lo) / 2;
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p90(&self) -> u64 {
        self.percentile(0.90)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(1023), 10);
        assert_eq!(Histogram::bucket_of(1024), 11);
        assert_eq!(Histogram::bucket_of(u64::MAX), N_BUCKETS - 1);
        assert_eq!(Histogram::bucket_upper(0), 0);
        assert_eq!(Histogram::bucket_upper(10), 1023);
        assert_eq!(Histogram::bucket_upper(N_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn constant_stream_is_exact() {
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record(777);
        }
        assert_eq!(h.p50(), 777);
        assert_eq!(h.p99(), 777);
        assert_eq!(h.count(), 100);
        assert_eq!(h.mean(), 777.0);
    }

    #[test]
    fn percentiles_are_ordered_and_bounded() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let (p50, p90, p99) = (h.p50(), h.p90(), h.p99());
        assert!(p50 <= p90 && p90 <= p99);
        assert!(p99 <= h.max());
        assert!(p50 >= h.min());
        // p50 of 1..=1000 is 500; log-bucket resolution is a factor of 2.
        assert!((250..=1000).contains(&p50), "p50 = {p50}");
    }

    #[test]
    fn zero_values_and_empty() {
        let empty = Histogram::new();
        assert_eq!(empty.p50(), 0);
        assert_eq!(empty.mean(), 0.0);
        assert_eq!(empty.min(), 0);
        let mut h = Histogram::new();
        h.record(0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 10);
        assert_eq!(a.max(), 1000);
        assert_eq!(a.sum(), 1010);
    }
}
