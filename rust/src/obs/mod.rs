//! Observability: a low-overhead structured event tracer plus exporters.
//!
//! The tracer is a preallocated ring buffer of [`TraceEvent`]s (64 bytes-ish
//! each, `Copy`, no heap traffic per event). When tracing is disabled the
//! per-event cost is one branch on [`Tracer::on`] — the hot paths in the
//! simulator and the live coordinator guard every `record` call with it, so
//! a disabled tracer adds nothing measurable (acceptance: < 2% on
//! `micro_hotpaths`). When the ring fills, the oldest events are overwritten
//! and counted in `dropped`.
//!
//! Event taxonomy (see DESIGN.md §5):
//! - job lifecycle: [`TraceEvent::JobArrive`] / [`TraceEvent::JobComplete`]
//! - per-task span edges: `TaskEnqueue` → `ExecStart` → `ExecEnd`, from
//!   which queue-wait and execute phases are reconstructed; `FetchStart` /
//!   `FetchEnd` give the model-fetch phase
//! - scheduler decisions: [`TraceEvent::Decision`] carries the candidate
//!   workers each scheduler scored (via [`crate::sched::DecisionProbe`]) and
//!   the one it chose, for Compass, HEFT, Hash, and JIT alike
//! - GPU cache traffic: `CacheHit` / `CacheMiss` / `CacheInsert` /
//!   `CacheEvict`
//! - SST health: [`TraceEvent::SstStaleness`] samples
//! - faults and recovery (DESIGN.md §9): [`TraceEvent::WorkerFailed`] /
//!   [`TraceEvent::TaskRetried`] / [`TraceEvent::TaskRePlaced`] /
//!   [`TraceEvent::JobDegraded`]
//!
//! Exporters: [`chrome::chrome_trace`] (Chrome `trace_event` JSON, one track
//! per worker, loadable in Perfetto / `chrome://tracing`) and
//! [`prom::prometheus_snapshot`] (Prometheus text exposition format).

pub mod chrome;
pub mod hist;
pub mod prom;

pub use hist::Histogram;

use crate::core::{JobId, Micros, ModelId};
use crate::dfg::PipelineKind;
use std::collections::BTreeMap;

/// Max scored candidates kept per scheduling decision. Schedulers may score
/// every worker; the probe keeps the best `MAX_CANDIDATES` by score and
/// counts the rest in [`CandidateSet::total`].
pub const MAX_CANDIDATES: usize = 8;

/// The candidate workers a scheduler scored for one task, best-first is NOT
/// guaranteed — entries keep insertion order, with worst-by-score evicted
/// once full. `score_us` is scheduler-specific but always "lower is better"
/// (finish-time or start-time estimates, µs).
#[derive(Debug, Clone, Copy, Default)]
pub struct CandidateSet {
    n: u8,
    /// Total candidates offered, including those evicted from the top-k.
    pub total: u16,
    workers: [u16; MAX_CANDIDATES],
    scores_us: [Micros; MAX_CANDIDATES],
}

impl CandidateSet {
    pub fn push(&mut self, worker: u16, score_us: Micros) {
        self.total = self.total.saturating_add(1);
        let n = self.n as usize;
        if n < MAX_CANDIDATES {
            self.workers[n] = worker;
            self.scores_us[n] = score_us;
            self.n += 1;
            return;
        }
        // Full: replace the current worst if this one scores better.
        let mut worst = 0;
        for i in 1..MAX_CANDIDATES {
            if self.scores_us[i] > self.scores_us[worst] {
                worst = i;
            }
        }
        if score_us < self.scores_us[worst] {
            self.workers[worst] = worker;
            self.scores_us[worst] = score_us;
        }
    }

    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn iter(&self) -> impl Iterator<Item = (u16, Micros)> + '_ {
        (0..self.n as usize).map(|i| (self.workers[i], self.scores_us[i]))
    }

    pub fn contains(&self, worker: u16) -> bool {
        self.iter().any(|(w, _)| w == worker)
    }
}

/// Which scheduling pass produced a [`TraceEvent::Decision`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPhase {
    /// Static planning at job arrival (Compass Algorithm 1 / HEFT / Hash).
    Plan,
    /// Dynamic adjustment at dispatch time (Compass Algorithm 2 / JIT).
    Adjust,
}

impl SchedPhase {
    pub fn name(self) -> &'static str {
        match self {
            SchedPhase::Plan => "plan",
            SchedPhase::Adjust => "adjust",
        }
    }
}

/// One structured trace event. All variants are `Copy` and timestamped in
/// simulated/relative microseconds (`t`).
#[derive(Debug, Clone, Copy)]
pub enum TraceEvent {
    JobArrive { job: JobId, kind: PipelineKind, t: Micros },
    JobComplete { job: JobId, kind: PipelineKind, latency_us: Micros, t: Micros },
    TaskEnqueue { job: JobId, task: u16, worker: u16, t: Micros },
    ExecStart { job: JobId, task: u16, worker: u16, t: Micros },
    ExecEnd { job: JobId, task: u16, worker: u16, t: Micros },
    FetchStart { worker: u16, model: ModelId, t: Micros },
    FetchEnd { worker: u16, model: ModelId, t: Micros },
    Decision {
        job: JobId,
        task: u16,
        phase: SchedPhase,
        /// Worker (or ingress node) that ran the scheduling logic.
        decider: u16,
        chosen: u16,
        candidates: CandidateSet,
        t: Micros,
    },
    CacheHit { worker: u16, model: ModelId, free_bytes: u64, t: Micros },
    CacheMiss { worker: u16, model: ModelId, free_bytes: u64, t: Micros },
    CacheInsert { worker: u16, model: ModelId, free_bytes: u64, t: Micros },
    CacheEvict { worker: u16, model: ModelId, free_bytes: u64, t: Micros },
    SstStaleness { worker: u16, load_staleness_us: Micros, cache_staleness_us: Micros, t: Micros },
    /// A multi-candidate batch coalesced on a worker (size ≥ 1 members of
    /// one model, about to execute as one pass).
    BatchFormed { worker: u16, model: ModelId, size: u16, t: Micros },
    /// A batch execution finished; its `size` members all ended at `t`.
    BatchExecuted { worker: u16, model: ModelId, size: u16, t: Micros },
    /// The failure detector declared `worker` dead at `t` and poisoned its
    /// SST row; `detector` is the peer whose staleness check fired.
    WorkerFailed { worker: u16, detector: u16, t: Micros },
    /// A transient failure (model fetch) is being retried on `worker`;
    /// `attempt` is 0-based, so the first retry records attempt 0.
    TaskRetried { worker: u16, model: ModelId, attempt: u16, t: Micros },
    /// A task orphaned by a worker death was re-placed `from` → `to`
    /// through the ordinary planner path.
    TaskRePlaced { job: JobId, task: u16, from: u16, to: u16, t: Micros },
    /// A job finished, but only after fault recovery re-placed at least
    /// one of its tasks (terminal outcome `Degraded`).
    JobDegraded { job: JobId, kind: PipelineKind, t: Micros },
    /// A live worker's PJRT runtime failed to load; `attempt` is 1-based.
    /// After the last attempt the worker falls back to the stub runtime.
    RuntimeLoadFailed { worker: u16, attempt: u16, t: Micros },
}

impl TraceEvent {
    /// Timestamp, µs.
    pub fn t(&self) -> Micros {
        match *self {
            TraceEvent::JobArrive { t, .. }
            | TraceEvent::JobComplete { t, .. }
            | TraceEvent::TaskEnqueue { t, .. }
            | TraceEvent::ExecStart { t, .. }
            | TraceEvent::ExecEnd { t, .. }
            | TraceEvent::FetchStart { t, .. }
            | TraceEvent::FetchEnd { t, .. }
            | TraceEvent::Decision { t, .. }
            | TraceEvent::CacheHit { t, .. }
            | TraceEvent::CacheMiss { t, .. }
            | TraceEvent::CacheInsert { t, .. }
            | TraceEvent::CacheEvict { t, .. }
            | TraceEvent::SstStaleness { t, .. }
            | TraceEvent::BatchFormed { t, .. }
            | TraceEvent::BatchExecuted { t, .. }
            | TraceEvent::WorkerFailed { t, .. }
            | TraceEvent::TaskRetried { t, .. }
            | TraceEvent::TaskRePlaced { t, .. }
            | TraceEvent::JobDegraded { t, .. }
            | TraceEvent::RuntimeLoadFailed { t, .. } => t,
        }
    }
}

/// Tracer configuration, embedded in [`crate::config::ClusterConfig`].
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    pub enabled: bool,
    /// Ring capacity in events. 2^16 events ≈ 5 MB resident.
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig { enabled: false, capacity: 1 << 16 }
    }
}

/// Preallocated ring-buffer event recorder. Construct with
/// [`Tracer::from_config`]; a disabled tracer never allocates.
#[derive(Debug)]
pub struct Tracer {
    buf: Vec<TraceEvent>,
    /// Next overwrite position once the ring is full.
    head: usize,
    dropped: u64,
    enabled: bool,
    cap: usize,
}

impl Tracer {
    /// A disabled tracer: `on()` is false, `record` is a no-op.
    pub fn off() -> Tracer {
        Tracer { buf: Vec::new(), head: 0, dropped: 0, enabled: false, cap: 0 }
    }

    pub fn from_config(tc: TraceConfig) -> Tracer {
        if !tc.enabled || tc.capacity == 0 {
            return Tracer::off();
        }
        Tracer {
            buf: Vec::with_capacity(tc.capacity),
            head: 0,
            dropped: 0,
            enabled: true,
            cap: tc.capacity,
        }
    }

    /// Cheap guard for hot paths: skip event construction entirely when
    /// tracing is disabled.
    #[inline(always)]
    pub fn on(&self) -> bool {
        self.enabled
    }

    #[inline]
    pub fn record(&mut self, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Events in chronological order (oldest surviving first).
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    /// Drain into an owned [`Trace`], leaving the tracer empty (but still
    /// enabled).
    pub fn take(&mut self) -> Trace {
        let events = self.events();
        self.buf.clear();
        let dropped = std::mem::take(&mut self.dropped);
        self.head = 0;
        Trace { events, dropped }
    }
}

/// A reconstructed per-task execution span.
#[derive(Debug, Clone, Copy)]
pub struct TaskSpan {
    pub job: JobId,
    pub task: u16,
    pub worker: u16,
    pub enqueue_us: Micros,
    pub start_us: Micros,
    pub end_us: Micros,
}

impl TaskSpan {
    pub fn queue_wait_us(&self) -> Micros {
        self.start_us.saturating_sub(self.enqueue_us)
    }

    pub fn exec_us(&self) -> Micros {
        self.end_us.saturating_sub(self.start_us)
    }
}

/// A reconstructed model-fetch span on one worker.
#[derive(Debug, Clone, Copy)]
pub struct FetchSpan {
    pub worker: u16,
    pub model: ModelId,
    pub start_us: Micros,
    pub end_us: Micros,
}

/// An owned, finished trace — what exporters and tests consume.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    /// Oldest events overwritten because the ring filled.
    pub dropped: u64,
}

impl Trace {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Reconstruct completed task spans by matching Enqueue → ExecStart →
    /// ExecEnd per (job, task). Tasks whose edges fell off the ring are
    /// skipped.
    pub fn task_spans(&self) -> Vec<TaskSpan> {
        let mut enq: BTreeMap<(JobId, u16), Micros> = BTreeMap::new();
        let mut started: BTreeMap<(JobId, u16), (u16, Micros, Micros)> = BTreeMap::new();
        let mut out = Vec::new();
        for ev in &self.events {
            match *ev {
                TraceEvent::TaskEnqueue { job, task, t, .. } => {
                    enq.insert((job, task), t);
                }
                TraceEvent::ExecStart { job, task, worker, t } => {
                    let e = enq.remove(&(job, task)).unwrap_or(t);
                    started.insert((job, task), (worker, e, t));
                }
                TraceEvent::ExecEnd { job, task, worker, t } => {
                    if let Some((w, e, s)) = started.remove(&(job, task)) {
                        debug_assert_eq!(w, worker);
                        out.push(TaskSpan {
                            job,
                            task,
                            worker,
                            enqueue_us: e,
                            start_us: s,
                            end_us: t,
                        });
                    }
                }
                _ => {}
            }
        }
        out
    }

    /// Reconstruct completed model-fetch spans per (worker, model).
    pub fn fetch_spans(&self) -> Vec<FetchSpan> {
        let mut open: BTreeMap<(u16, ModelId), Micros> = BTreeMap::new();
        let mut out = Vec::new();
        for ev in &self.events {
            match *ev {
                TraceEvent::FetchStart { worker, model, t } => {
                    open.insert((worker, model), t);
                }
                TraceEvent::FetchEnd { worker, model, t } => {
                    if let Some(s) = open.remove(&(worker, model)) {
                        out.push(FetchSpan { worker, model, start_us: s, end_us: t });
                    }
                }
                _ => {}
            }
        }
        out
    }

    /// Histogram of queue-wait phases, µs.
    pub fn queue_wait_hist(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in self.task_spans() {
            h.record(s.queue_wait_us());
        }
        h
    }

    /// Histogram of execute phases, µs.
    pub fn exec_hist(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in self.task_spans() {
            h.record(s.exec_us());
        }
        h
    }

    /// Histogram of model-fetch phases, µs.
    pub fn fetch_hist(&self) -> Histogram {
        let mut h = Histogram::new();
        for s in self.fetch_spans() {
            h.record(s.end_us.saturating_sub(s.start_us));
        }
        h
    }

    /// Histogram of end-to-end job latencies, µs.
    pub fn job_latency_hist(&self) -> Histogram {
        let mut h = Histogram::new();
        for ev in &self.events {
            if let TraceEvent::JobComplete { latency_us, .. } = *ev {
                h.record(latency_us);
            }
        }
        h
    }

    /// Histogram of SST load-row staleness samples, µs.
    pub fn sst_staleness_hist(&self) -> Histogram {
        let mut h = Histogram::new();
        for ev in &self.events {
            if let TraceEvent::SstStaleness { load_staleness_us, .. } = *ev {
                h.record(load_staleness_us);
            }
        }
        h
    }

    /// Histogram of executed batch sizes (unitless member counts; includes
    /// size-1 batches, so the distribution shows how often coalescing won).
    pub fn batch_size_hist(&self) -> Histogram {
        let mut h = Histogram::new();
        for ev in &self.events {
            if let TraceEvent::BatchExecuted { size, .. } = *ev {
                h.record(size as u64);
            }
        }
        h
    }

    pub fn count<F: Fn(&TraceEvent) -> bool>(&self, f: F) -> usize {
        self.events.iter().filter(|e| f(e)).count()
    }
}

/// Write the requested exporter outputs. Shared by the `simulate`, `serve`
/// and `experiment` CLI entry points (`--trace-out` / `--metrics-out`).
pub fn write_outputs(
    trace: &Trace,
    metrics: &crate::metrics::MetricsSink,
    trace_out: Option<&std::path::Path>,
    metrics_out: Option<&std::path::Path>,
) -> std::io::Result<()> {
    if let Some(p) = trace_out {
        std::fs::write(p, chrome::chrome_trace(trace))?;
    }
    if let Some(p) = metrics_out {
        std::fs::write(p, prom::prometheus_snapshot(metrics, Some(trace)))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn instant(t: Micros) -> TraceEvent {
        TraceEvent::CacheHit { worker: 0, model: 0, free_bytes: 0, t }
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut tr = Tracer::off();
        assert!(!tr.on());
        tr.record(instant(1));
        assert!(tr.is_empty());
        assert_eq!(tr.take().events.len(), 0);
    }

    #[test]
    fn ring_wraparound_keeps_newest() {
        let mut tr = Tracer::from_config(TraceConfig { enabled: true, capacity: 4 });
        for t in 0..10 {
            tr.record(instant(t));
        }
        assert_eq!(tr.len(), 4);
        assert_eq!(tr.dropped(), 6);
        let evs = tr.events();
        let ts: Vec<Micros> = evs.iter().map(|e| e.t()).collect();
        // Oldest surviving first, strictly chronological.
        assert_eq!(ts, vec![6, 7, 8, 9]);
        let trace = tr.take();
        assert_eq!(trace.dropped, 6);
        assert!(tr.is_empty());
        assert_eq!(tr.dropped(), 0);
    }

    #[test]
    fn candidate_set_keeps_best_k() {
        let mut c = CandidateSet::default();
        for w in 0..12u16 {
            // Scores descend: later offers are better.
            c.push(w, (100 - w as u64) * 10);
        }
        assert_eq!(c.len(), MAX_CANDIDATES);
        assert_eq!(c.total, 12);
        // The 8 best scores are those of workers 4..12.
        for w in 4..12 {
            assert!(c.contains(w), "worker {w} should survive");
        }
        assert!(!c.contains(0));
    }

    #[test]
    fn batch_size_hist_counts_executed_batches() {
        let trace = Trace {
            events: vec![
                TraceEvent::BatchFormed { worker: 0, model: 1, size: 4, t: 10 },
                TraceEvent::BatchExecuted { worker: 0, model: 1, size: 4, t: 50 },
                TraceEvent::BatchExecuted { worker: 1, model: 2, size: 1, t: 60 },
            ],
            dropped: 0,
        };
        let h = trace.batch_size_hist();
        assert_eq!(h.count(), 2);
        assert!(h.max() >= 4);
    }

    #[test]
    fn span_reconstruction() {
        let trace = Trace {
            events: vec![
                TraceEvent::TaskEnqueue { job: 1, task: 0, worker: 2, t: 10 },
                TraceEvent::ExecStart { job: 1, task: 0, worker: 2, t: 25 },
                TraceEvent::ExecEnd { job: 1, task: 0, worker: 2, t: 75 },
                TraceEvent::FetchStart { worker: 2, model: 3, t: 12 },
                TraceEvent::FetchEnd { worker: 2, model: 3, t: 22 },
                // Unfinished task: must not produce a span.
                TraceEvent::ExecStart { job: 2, task: 0, worker: 0, t: 80 },
            ],
            dropped: 0,
        };
        let spans = trace.task_spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].queue_wait_us(), 15);
        assert_eq!(spans[0].exec_us(), 50);
        let fetches = trace.fetch_spans();
        assert_eq!(fetches.len(), 1);
        assert_eq!(fetches[0].end_us - fetches[0].start_us, 10);
        assert_eq!(trace.queue_wait_hist().count(), 1);
        assert_eq!(trace.exec_hist().p50(), 50);
    }
}
