//! Transfer-cost model (paper §4.1 and Figure 4).
//!
//! Two links matter to the scheduler:
//!   * worker ↔ worker network (RDMA/DPDK): `TD_input(t) =
//!     |input|/net_bw + δ_network` — charged when a task consumes an input
//!     produced on a *different* worker (co-located transfers are free,
//!     §5.1.2).
//!   * host ↔ GPU PCIe: `TD_model(m, w) = |m|/pcie_bw + δ_PCIe` — charged
//!     when a model must be fetched from host memory into the GPU cache.
//!
//! Defaults are calibrated to the paper's testbed: 100 Gbps InfiniBand
//! (12.5 GB/s) and PCIe 3.0 x16-class bandwidth to a Tesla T4 (~12 GB/s).

use crate::core::{Micros, MS};

#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Worker-to-worker bandwidth in bytes/µs (12_500 = 100 Gbps).
    pub net_bytes_per_us: f64,
    /// Fixed per-transfer network latency δ_network, µs.
    pub delta_net_us: Micros,
    /// Host-to-GPU PCIe bandwidth in bytes/µs (12_000 = 12 GB/s).
    pub pcie_bytes_per_us: f64,
    /// Fixed per-fetch PCIe setup cost δ_PCIe, µs.
    pub delta_pcie_us: Micros,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            net_bytes_per_us: 12_500.0,
            delta_net_us: 50,
            pcie_bytes_per_us: 12_000.0,
            delta_pcie_us: 2 * MS,
        }
    }
}

impl CostModel {
    /// TD for moving `bytes` between two *different* workers.
    #[inline]
    pub fn td_transfer(&self, bytes: u64) -> Micros {
        (bytes as f64 / self.net_bytes_per_us) as Micros + self.delta_net_us
    }

    /// TD for moving `bytes` from worker `src` to `dst` (0 if co-located).
    #[inline]
    pub fn td_input(&self, bytes: u64, src: usize, dst: usize) -> Micros {
        if src == dst {
            0
        } else {
            self.td_transfer(bytes)
        }
    }

    /// TD for fetching a model of `bytes` from host memory into GPU memory.
    #[inline]
    pub fn td_model(&self, bytes: u64) -> Micros {
        (bytes as f64 / self.pcie_bytes_per_us) as Micros + self.delta_pcie_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{GB, SEC};

    #[test]
    fn colocated_transfer_is_free() {
        let c = CostModel::default();
        assert_eq!(c.td_input(5 * GB, 2, 2), 0);
        assert!(c.td_input(5 * GB, 2, 3) > 0);
    }

    #[test]
    fn model_fetch_magnitude_matches_testbed() {
        // 5 GB over ~12 GB/s PCIe ≈ 0.42 s — the "costly last-instant fetch"
        // the paper's cache management exists to avoid.
        let c = CostModel::default();
        let td = c.td_model(5 * GB);
        assert!(td > 300 * MS && td < SEC, "td={td}");
    }

    #[test]
    fn network_faster_than_pcie_per_paper() {
        // §5.1.2: DMA from host ≈ RDMA from a remote host, same order.
        let c = CostModel::default();
        let net = c.td_transfer(GB);
        let pcie = c.td_model(GB);
        assert!((net as f64) < (pcie as f64) * 1.5);
    }

    #[test]
    fn delta_dominates_small_transfers() {
        let c = CostModel::default();
        assert_eq!(c.td_transfer(0), c.delta_net_us);
        assert!(c.td_transfer(1000) < 2 * c.delta_net_us);
    }
}
