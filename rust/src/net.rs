//! Transfer-cost model (paper §4.1 and Figure 4).
//!
//! Two links matter to the scheduler:
//!   * worker ↔ worker network (RDMA/DPDK): `TD_input(t) =
//!     |input|/net_bw + δ_network` — charged when a task consumes an input
//!     produced on a *different* worker (co-located transfers are free,
//!     §5.1.2).
//!   * host ↔ GPU PCIe: `TD_model(m, w) = |m|/pcie_bw + δ_PCIe` — charged
//!     when a model must be fetched from host memory into the GPU cache.
//!
//! Defaults are calibrated to the paper's testbed: 100 Gbps InfiniBand
//! (12.5 GB/s) and PCIe 3.0 x16-class bandwidth to a Tesla T4 (~12 GB/s).

use crate::core::{Micros, MS};

#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Worker-to-worker bandwidth in bytes/µs (12_500 = 100 Gbps).
    pub net_bytes_per_us: f64,
    /// Fixed per-transfer network latency δ_network, µs.
    pub delta_net_us: Micros,
    /// Host-to-GPU PCIe bandwidth in bytes/µs (12_000 = 12 GB/s).
    pub pcie_bytes_per_us: f64,
    /// Fixed per-fetch PCIe setup cost δ_PCIe, µs.
    pub delta_pcie_us: Micros,
    /// Per-model batching on the worker execute path (§5 batching windows).
    pub batch: BatchConfig,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            net_bytes_per_us: 12_500.0,
            delta_net_us: 50,
            pcie_bytes_per_us: 12_000.0,
            delta_pcie_us: 2 * MS,
            batch: BatchConfig::default(),
        }
    }
}

/// Batching knobs for the worker execute path. With `batch_max = 1`
/// (the default) batching is fully disabled and every execution path is
/// bit-identical to the unbatched scheduler.
///
/// The cost curve follows the sublinear law `R_batch(b) = R · (alpha +
/// (1-alpha)·b)` for b same-runtime members: a batch costs one "full"
/// activation pass plus a discounted marginal pass per extra member.
/// Generalized to mixed solo runtimes as `alpha·max + (1-alpha)·sum`,
/// which reduces to R at b = 1 for any alpha.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Max same-model queue entries coalesced into one execution (1 = off).
    pub batch_max: usize,
    /// How long a lone task holds the GPU idle waiting for queue-mates, µs.
    pub window_us: Micros,
    /// Global alpha override; `None` uses each model's profiled
    /// `batch_alpha` from `dfg::models`.
    pub alpha_override: Option<f64>,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { batch_max: 1, window_us: MS, alpha_override: None }
    }
}

impl BatchConfig {
    /// Batching changes behavior only past batch size 1.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.batch_max > 1
    }

    /// Effective alpha for a model whose profiled alpha is `model_alpha`.
    #[inline]
    pub fn alpha(&self, model_alpha: f64) -> f64 {
        self.alpha_override.unwrap_or(model_alpha).clamp(0.0, 1.0)
    }

    /// Runtime of one batch whose members have max solo runtime `max_us`
    /// and summed solo runtime `sum_us`.
    #[inline]
    pub fn batch_runtime_us(&self, max_us: Micros, sum_us: Micros, alpha: f64) -> Micros {
        if max_us == sum_us {
            // Single member (or degenerate zero-runtime mates): exactly the
            // solo runtime, no float rounding.
            return sum_us;
        }
        (alpha * max_us as f64 + (1.0 - alpha) * sum_us as f64) as Micros
    }

    /// Estimated time to drain `count` queued same-model tasks of summed
    /// solo runtime `sum_us` under coalescing: each of the ⌈count/max⌉
    /// batches pays one mean-runtime "full" pass, every member pays the
    /// `(1-alpha)` marginal pass. Exactly `sum_us` when batching is off.
    #[inline]
    pub fn drain_estimate_us(&self, count: usize, sum_us: Micros, alpha: f64) -> Micros {
        if self.batch_max <= 1 || count <= 1 {
            return sum_us;
        }
        let batches = (count + self.batch_max - 1) / self.batch_max;
        let mean = sum_us as f64 / count as f64;
        ((1.0 - alpha) * sum_us as f64 + alpha * mean * batches as f64) as Micros
    }
}

impl CostModel {
    /// TD for moving `bytes` between two *different* workers.
    #[inline]
    pub fn td_transfer(&self, bytes: u64) -> Micros {
        (bytes as f64 / self.net_bytes_per_us) as Micros + self.delta_net_us
    }

    /// TD for moving `bytes` from worker `src` to `dst` (0 if co-located).
    #[inline]
    pub fn td_input(&self, bytes: u64, src: usize, dst: usize) -> Micros {
        if src == dst {
            0
        } else {
            self.td_transfer(bytes)
        }
    }

    /// TD for fetching a model of `bytes` from host memory into GPU memory.
    #[inline]
    pub fn td_model(&self, bytes: u64) -> Micros {
        (bytes as f64 / self.pcie_bytes_per_us) as Micros + self.delta_pcie_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{GB, SEC};

    #[test]
    fn colocated_transfer_is_free() {
        let c = CostModel::default();
        assert_eq!(c.td_input(5 * GB, 2, 2), 0);
        assert!(c.td_input(5 * GB, 2, 3) > 0);
    }

    #[test]
    fn model_fetch_magnitude_matches_testbed() {
        // 5 GB over ~12 GB/s PCIe ≈ 0.42 s — the "costly last-instant fetch"
        // the paper's cache management exists to avoid.
        let c = CostModel::default();
        let td = c.td_model(5 * GB);
        assert!(td > 300 * MS && td < SEC, "td={td}");
    }

    #[test]
    fn network_faster_than_pcie_per_paper() {
        // §5.1.2: DMA from host ≈ RDMA from a remote host, same order.
        let c = CostModel::default();
        let net = c.td_transfer(GB);
        let pcie = c.td_model(GB);
        assert!((net as f64) < (pcie as f64) * 1.5);
    }

    #[test]
    fn delta_dominates_small_transfers() {
        let c = CostModel::default();
        assert_eq!(c.td_transfer(0), c.delta_net_us);
        assert!(c.td_transfer(1000) < 2 * c.delta_net_us);
    }

    #[test]
    fn batching_off_by_default() {
        let b = CostModel::default().batch;
        assert!(!b.enabled());
        assert_eq!(b.batch_max, 1);
    }

    #[test]
    fn batch_runtime_reduces_to_solo_at_b1() {
        let b = BatchConfig { batch_max: 8, ..Default::default() };
        for alpha in [0.0, 0.3, 0.5, 1.0] {
            assert_eq!(b.batch_runtime_us(7000, 7000, alpha), 7000);
        }
    }

    #[test]
    fn batch_runtime_sublinear_in_members() {
        let b = BatchConfig { batch_max: 8, ..Default::default() };
        // 4 members of 10 ms each at alpha 0.5: 0.5·10 + 0.5·40 = 25 ms,
        // strictly between one member (10) and serial execution (40).
        let r = b.batch_runtime_us(10_000, 40_000, 0.5);
        assert_eq!(r, 25_000);
        assert!(r > 10_000 && r < 40_000);
    }

    #[test]
    fn drain_estimate_exact_when_disabled() {
        let b = BatchConfig::default();
        assert_eq!(b.drain_estimate_us(5, 50_000, 0.5), 50_000);
        let on = BatchConfig { batch_max: 4, ..Default::default() };
        assert_eq!(on.drain_estimate_us(1, 9000, 0.5), 9000);
    }

    #[test]
    fn drain_estimate_matches_batch_runtime_for_uniform_queue() {
        // 8 tasks of 10 ms, batch_max 4 → two batches of 4: each
        // 0.5·10 + 0.5·40 = 25 ms, total 50 ms.
        let b = BatchConfig { batch_max: 4, ..Default::default() };
        assert_eq!(b.drain_estimate_us(8, 80_000, 0.5), 50_000);
    }

    #[test]
    fn alpha_override_wins_and_clamps() {
        let b = BatchConfig { batch_max: 2, alpha_override: Some(2.0), ..Default::default() };
        assert_eq!(b.alpha(0.5), 1.0);
        let b = BatchConfig { batch_max: 2, ..Default::default() };
        assert_eq!(b.alpha(0.6), 0.6);
    }
}
