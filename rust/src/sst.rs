//! Shared State Table — the decentralized Global State Monitor (§3.4, §5.2).
//!
//! One cache-line-sized row per worker, replicated to all peers. A worker
//! updates its *live* state continuously but only *pushes* (publishes) at a
//! rate-limited interval; peers therefore see each row with bounded
//! staleness equal to the push interval. The paper separates two kinds of
//! state — queue-load (finish-time estimate) and GPU cache contents
//! (bitmap + free bytes) — and Figure 8 varies their push rates on
//! independent axes, so we keep two independent push timers per row.

use crate::core::{Micros, WorkerId};

/// Sentinel FT marking a row *poisoned*: its worker has been declared dead
/// by the failure detector (DESIGN.md §9). Schedulers must treat a
/// poisoned row as "never finishes" and mask the worker out *before* any
/// finish-time arithmetic — adding to the sentinel would overflow.
pub const POISONED_FT: Micros = Micros::MAX;

/// The published, cache-line-sized row (paper Figure 5): fits in 64 bytes.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SstRow {
    /// FT(w): estimated absolute time at which all tasks currently on the
    /// worker's execution queue will have finished, µs.
    pub ft_us: Micros,
    /// Cache bitmap: bit i set ⇔ model i resident in the Navigator cache.
    pub cache_bitmap: u64,
    /// AVC(w): free Navigator-cache bytes.
    pub free_cache_bytes: u64,
    /// Push timestamps (diagnostics / staleness accounting).
    pub load_pushed_at: Micros,
    pub cache_pushed_at: Micros,
}

impl SstRow {
    /// Has this worker been declared dead?
    #[inline]
    pub fn poisoned(&self) -> bool {
        self.ft_us == POISONED_FT
    }
}

/// Whole-cluster SST: the *published* view every worker replicates.
///
/// In the live coordinator this sits behind a lock updated only by push
/// events (mimicking the RDMA row writes); in the simulator push events
/// copy live worker state in. Readers always go through `row()` /
/// `rows()` — they can never observe un-pushed state of a peer.
#[derive(Debug, Clone)]
pub struct Sst {
    rows: Vec<SstRow>,
}

impl Sst {
    pub fn new(n_workers: usize) -> Sst {
        Sst { rows: vec![SstRow::default(); n_workers] }
    }

    pub fn n_workers(&self) -> usize {
        self.rows.len()
    }

    pub fn row(&self, w: WorkerId) -> &SstRow {
        &self.rows[w]
    }

    pub fn rows(&self) -> &[SstRow] {
        &self.rows
    }

    /// Push the load half of a row (FT estimate).
    pub fn push_load(&mut self, w: WorkerId, ft_us: Micros, now: Micros) {
        let r = &mut self.rows[w];
        r.ft_us = ft_us;
        r.load_pushed_at = now;
    }

    /// Push the cache half of a row (bitmap + free bytes).
    pub fn push_cache(&mut self, w: WorkerId, bitmap: u64, free_bytes: u64, now: Micros) {
        let r = &mut self.rows[w];
        r.cache_bitmap = bitmap;
        r.free_cache_bytes = free_bytes;
        r.cache_pushed_at = now;
    }

    /// Declare worker `w` dead: pin its FT to the [`POISONED_FT`] sentinel
    /// so every scheduler masks it out, and stamp the push timestamps so
    /// the row stops reading as stale (it is *known* dead, not silent).
    /// Idempotent — detection races in the live cluster may claim twice.
    pub fn poison(&mut self, w: WorkerId, now: Micros) {
        let r = &mut self.rows[w];
        if r.poisoned() {
            return;
        }
        r.ft_us = POISONED_FT;
        r.cache_bitmap = 0;
        r.free_cache_bytes = 0;
        r.load_pushed_at = now;
        r.cache_pushed_at = now;
    }

    /// Failure-detector predicate (DESIGN.md §9): the heartbeat is the
    /// existing load push, so a worker whose load half has not been pushed
    /// within `timeout` is suspected dead. Already-poisoned rows are not
    /// stale — they are resolved.
    pub fn is_stale(&self, w: WorkerId, now: Micros, timeout: Micros) -> bool {
        let r = &self.rows[w];
        !r.poisoned() && now.saturating_sub(r.load_pushed_at) > timeout
    }

    /// Worst-case load-information staleness across peers as seen at `now`.
    /// Poisoned rows are excluded: a dead worker no longer pushes.
    pub fn max_load_staleness(&self, now: Micros) -> Micros {
        self.rows
            .iter()
            .filter(|r| !r.poisoned())
            .map(|r| now.saturating_sub(r.load_pushed_at))
            .max()
            .unwrap_or(0)
    }

    /// Worst-case cache-information staleness across peers as seen at `now`.
    pub fn max_cache_staleness(&self, now: Micros) -> Micros {
        self.rows
            .iter()
            .filter(|r| !r.poisoned())
            .map(|r| now.saturating_sub(r.cache_pushed_at))
            .max()
            .unwrap_or(0)
    }

    /// Per-row staleness of both halves at `now`: (load, cache), µs — the
    /// observability layer samples these into SstStaleness events.
    pub fn staleness_of(&self, w: WorkerId, now: Micros) -> (Micros, Micros) {
        let r = &self.rows[w];
        (now.saturating_sub(r.load_pushed_at), now.saturating_sub(r.cache_pushed_at))
    }
}

/// Push-rate limiter configuration (§5.2: experiments justify 5 pushes/s;
/// Figure 8 sweeps both axes).
#[derive(Debug, Clone, Copy)]
pub struct PushConfig {
    /// Interval between load (FT) pushes, µs.
    pub load_interval_us: Micros,
    /// Interval between cache (bitmap/free) pushes, µs.
    pub cache_interval_us: Micros,
}

impl Default for PushConfig {
    fn default() -> Self {
        // 5 pushes/s = 200 ms, the paper's chosen operating point.
        PushConfig { load_interval_us: 200_000, cache_interval_us: 200_000 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_start_empty() {
        let sst = Sst::new(3);
        assert_eq!(sst.n_workers(), 3);
        assert_eq!(sst.row(1).cache_bitmap, 0);
    }

    #[test]
    fn pushes_are_independent_halves() {
        let mut sst = Sst::new(2);
        sst.push_load(0, 500, 100);
        sst.push_cache(0, 0b101, 7, 200);
        let r = sst.row(0);
        assert_eq!(r.ft_us, 500);
        assert_eq!(r.cache_bitmap, 0b101);
        assert_eq!(r.load_pushed_at, 100);
        assert_eq!(r.cache_pushed_at, 200);
    }

    #[test]
    fn reader_sees_only_pushed_state() {
        // The SST has no API to read anything that wasn't pushed: updating
        // live worker state elsewhere cannot leak here. Push, then verify
        // the old value persists until the next push.
        let mut sst = Sst::new(1);
        sst.push_load(0, 1000, 0);
        // (live FT changes to 2000 at t=50, but no push happens)
        assert_eq!(sst.row(0).ft_us, 1000);
        sst.push_load(0, 2000, 200_000);
        assert_eq!(sst.row(0).ft_us, 2000);
    }

    #[test]
    fn staleness_bound() {
        let mut sst = Sst::new(2);
        sst.push_load(0, 0, 100);
        sst.push_load(1, 0, 300);
        assert_eq!(sst.max_load_staleness(500), 400);
    }

    #[test]
    fn cache_staleness_tracks_cache_pushes_only() {
        let mut sst = Sst::new(2);
        sst.push_cache(0, 0, 0, 100);
        sst.push_cache(1, 0, 0, 250);
        sst.push_load(0, 0, 490); // must not affect the cache axis
        assert_eq!(sst.max_cache_staleness(500), 400);
        assert_eq!(sst.staleness_of(0, 500), (10, 400));
        assert_eq!(sst.staleness_of(1, 500), (500, 250));
    }

    #[test]
    fn default_push_config_is_5_per_second() {
        let c = PushConfig::default();
        assert_eq!(c.load_interval_us, 200_000);
    }

    #[test]
    fn poison_is_terminal_and_idempotent() {
        let mut sst = Sst::new(2);
        sst.push_load(0, 500, 100);
        sst.push_cache(0, 0b11, 7, 100);
        sst.poison(0, 1000);
        assert!(sst.row(0).poisoned());
        assert_eq!(sst.row(0).ft_us, POISONED_FT);
        assert_eq!(sst.row(0).cache_bitmap, 0);
        // Second claim (a detection race) changes nothing.
        let snap = *sst.row(0);
        sst.poison(0, 9999);
        assert_eq!(*sst.row(0), snap);
        assert!(!sst.row(1).poisoned());
    }

    #[test]
    fn staleness_detector_thresholds() {
        let mut sst = Sst::new(2);
        sst.push_load(0, 0, 100);
        sst.push_load(1, 0, 100);
        assert!(!sst.is_stale(0, 300, 600));
        assert!(sst.is_stale(0, 701, 600));
        // A poisoned row is resolved, not stale — and drops out of the
        // staleness monitoring maxima.
        sst.poison(0, 800);
        assert!(!sst.is_stale(0, 10_000, 600));
        assert_eq!(sst.max_load_staleness(1100), 1000);
    }

    #[test]
    fn row_is_cacheline_sized() {
        // §5.2: the row must squeeze into a 64-byte cache line for atomic
        // RDMA pushes.
        assert!(std::mem::size_of::<SstRow>() <= 64);
    }
}
