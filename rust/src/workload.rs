//! Workload generation: Poisson mixed request streams (§6.2), input-size
//! sampling standing in for GLUE/COCO inputs, and an Alibaba-like bursty
//! production trace synthesizer (§6.4 substitution — see DESIGN.md §3).

use crate::core::{Micros, JobId, KB, SEC};
use crate::dfg::{Job, PipelineKind};
use crate::util::rng::Rng;

/// Sample an input size for a pipeline kind: text pipelines draw
/// GLUE-sentence-scale payloads, vision pipelines COCO-image-scale ones.
pub fn sample_input_bytes(kind: PipelineKind, rng: &mut Rng) -> u64 {
    match kind {
        // GLUE text: a few hundred bytes to a few KB.
        PipelineKind::Translation | PipelineKind::Vpa => {
            (rng.lognormal(6.5, 0.8) as u64).clamp(64, 16 * KB)
        }
        // COCO images: ~50-500 KB JPEG.
        PipelineKind::ImageCaption | PipelineKind::Perception => {
            (rng.lognormal(11.9, 0.5) as u64).clamp(20 * KB, 2_000 * KB)
        }
    }
}

/// A Poisson stream of `n_jobs` requests at `rate_per_s`, with kinds drawn
/// from `mix` (weights per `PipelineKind::ALL` order; uniform if empty).
pub fn poisson(rate_per_s: f64, n_jobs: usize, mix: &[f64], seed: u64) -> Vec<Job> {
    let mut rng = Rng::new(seed);
    let weights: Vec<f64> =
        if mix.is_empty() { vec![1.0; 4] } else { mix.to_vec() };
    let mut t = 0.0f64;
    let mut jobs = Vec::with_capacity(n_jobs);
    for id in 0..n_jobs {
        t += rng.exp(rate_per_s);
        let kind = PipelineKind::from_index(rng.weighted(&weights));
        jobs.push(Job {
            id: id as JobId,
            kind,
            arrival_us: (t * SEC as f64) as Micros,
            input_bytes: sample_input_bytes(kind, &mut rng),
        });
    }
    jobs
}

/// One bucket of the synthesized production trace (for Fig. 9a's timeline).
#[derive(Debug, Clone, Copy)]
pub struct TraceBucket {
    pub start_us: Micros,
    pub rate_per_s: f64,
}

/// Alibaba-production-like trace: a diurnal-ish base load modulated by
/// log-normal burst episodes, rescaled so the mean rate matches
/// `mean_rate_per_s` (the paper rescales the real trace to its cluster
/// capacity the same way). Returns (jobs, per-bucket rates for plotting).
pub fn alibaba_like(
    mean_rate_per_s: f64,
    duration_s: f64,
    seed: u64,
) -> (Vec<Job>, Vec<TraceBucket>) {
    let mut rng = Rng::new(seed);
    let bucket_s = 5.0f64;
    let n_buckets = (duration_s / bucket_s).ceil() as usize;

    // Base: slow sinusoid (diurnal ramp compressed to the experiment span).
    // Bursts: Poisson-arriving episodes with log-normal intensity and
    // geometric duration — the burst structure §6.4 stresses.
    let mut rates = vec![0.0f64; n_buckets];
    for (i, r) in rates.iter_mut().enumerate() {
        let phase = i as f64 / n_buckets as f64 * std::f64::consts::TAU;
        *r = 1.0 + 0.45 * (phase - 1.0).sin();
    }
    let mut i = 0usize;
    while i < n_buckets {
        if rng.f64() < 0.12 {
            let intensity = rng.lognormal(1.1, 0.6); // ~3x spikes
            let len = 1 + rng.below(3) as usize;
            for j in i..(i + len).min(n_buckets) {
                rates[j] += intensity;
            }
            i += len;
        } else {
            i += 1;
        }
    }
    // Rescale to the requested mean.
    let cur_mean = rates.iter().sum::<f64>() / n_buckets as f64;
    for r in rates.iter_mut() {
        *r *= mean_rate_per_s / cur_mean;
    }

    // Draw jobs bucket by bucket (Poisson within each bucket).
    let mut jobs = Vec::new();
    let mut buckets = Vec::with_capacity(n_buckets);
    let mut id: JobId = 0;
    for (i, &rate) in rates.iter().enumerate() {
        let start = i as f64 * bucket_s;
        buckets.push(TraceBucket {
            start_us: (start * SEC as f64) as Micros,
            rate_per_s: rate,
        });
        let mut t = 0.0;
        loop {
            t += rng.exp(rate.max(1e-6));
            if t >= bucket_s {
                break;
            }
            let kind = PipelineKind::from_index(rng.below(4) as usize);
            jobs.push(Job {
                id,
                kind,
                arrival_us: ((start + t) * SEC as f64) as Micros,
                input_bytes: sample_input_bytes(kind, &mut rng),
            });
            id += 1;
        }
    }
    jobs.sort_by_key(|j| j.arrival_us);
    (jobs, buckets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate() {
        let jobs = poisson(2.0, 4000, &[], 1);
        let span_s = jobs.last().unwrap().arrival_us as f64 / SEC as f64;
        let rate = jobs.len() as f64 / span_s;
        assert!((rate - 2.0).abs() < 0.15, "rate={rate}");
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let jobs = poisson(1.0, 500, &[], 2);
        for w in jobs.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us);
        }
    }

    #[test]
    fn poisson_mix_respected() {
        // Only translation jobs when the mix is a delta.
        let jobs = poisson(1.0, 200, &[1.0, 0.0, 0.0, 0.0], 3);
        assert!(jobs.iter().all(|j| j.kind == PipelineKind::Translation));
    }

    #[test]
    fn poisson_all_kinds_present_uniform() {
        let jobs = poisson(1.0, 400, &[], 4);
        for kind in PipelineKind::ALL {
            assert!(jobs.iter().any(|j| j.kind == kind), "{kind:?} missing");
        }
    }

    #[test]
    fn input_sizes_in_domain_bands() {
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let text = sample_input_bytes(PipelineKind::Vpa, &mut rng);
            let image = sample_input_bytes(PipelineKind::Perception, &mut rng);
            assert!(text <= 16 * KB);
            assert!(image >= 20 * KB);
        }
    }

    #[test]
    fn trace_mean_rate_rescaled() {
        let (jobs, buckets) = alibaba_like(3.0, 400.0, 6);
        let rate = jobs.len() as f64 / 400.0;
        assert!((rate - 3.0).abs() < 0.4, "rate={rate}");
        assert!(!buckets.is_empty());
    }

    #[test]
    fn trace_is_bursty() {
        let (_, buckets) = alibaba_like(2.0, 600.0, 7);
        let rates: Vec<f64> = buckets.iter().map(|b| b.rate_per_s).collect();
        let max = rates.iter().cloned().fold(0.0, f64::max);
        let mean = rates.iter().sum::<f64>() / rates.len() as f64;
        assert!(max > 2.0 * mean, "no bursts: max={max} mean={mean}");
    }

    #[test]
    fn trace_deterministic() {
        let (a, _) = alibaba_like(2.0, 100.0, 8);
        let (b, _) = alibaba_like(2.0, 100.0, 8);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrival_us == y.arrival_us));
    }
}
