//! Metrics collection: per-job latency and slow-down factor (§6.1), and the
//! Table 1 GPU metrics (utilization, memory utilization, energy, cache hit
//! rate).
//!
//! Energy uses a Tesla-T4-style linear power model: idle power plus a
//! utilization-proportional active term, integrated over the experiment.

use crate::core::{Micros, SEC};
use crate::dfg::PipelineKind;
use crate::util::stats::{mean, BoxStats};

/// Power model for a T4-class inference GPU.
pub const GPU_IDLE_WATTS: f64 = 10.0;
pub const GPU_ACTIVE_WATTS: f64 = 70.0;

/// Terminal outcome of a job (DESIGN.md §9). Failure-free runs only ever
/// produce `Completed`; under fault injection a job whose tasks had to be
/// re-placed after a worker death still finishes (`Degraded`), and a job
/// is `Failed` only when no alive worker remained to run it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum JobOutcome {
    #[default]
    Completed,
    Degraded,
    Failed,
}

/// One terminated job instance.
#[derive(Debug, Clone, Copy)]
pub struct JobRecord {
    pub kind: PipelineKind,
    pub arrival_us: Micros,
    pub completion_us: Micros,
    pub lower_bound_us: Micros,
    pub outcome: JobOutcome,
}

impl JobRecord {
    pub fn latency_us(&self) -> Micros {
        self.completion_us - self.arrival_us
    }

    /// §6.1: end-to-end latency over the zero-transfer, all-cached,
    /// max-parallelism lower bound. Always ≥ 1 in expectation.
    pub fn slowdown(&self) -> f64 {
        self.latency_us() as f64 / self.lower_bound_us as f64
    }

    /// Did the job terminate without producing its result? Failed records
    /// carry the failure time in `completion_us`, so their latency is not
    /// an end-to-end latency — latency statistics exclude them.
    pub fn failed(&self) -> bool {
        self.outcome == JobOutcome::Failed
    }
}

/// Fault-injection and recovery counters (DESIGN.md §9), zero in any
/// failure-free run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Workers declared dead by the staleness detector.
    pub workers_failed: u64,
    /// Orphaned tasks re-placed through the planner after a worker death.
    pub tasks_re_placed: u64,
    /// Transient-failure retries (model fetch today).
    pub task_retries: u64,
    /// Jobs that reached the `Failed` outcome.
    pub jobs_failed: u64,
}

/// Per-worker aggregates sampled at simulation end.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerMetrics {
    pub busy_us: Micros,
    pub hits: u64,
    pub misses: u64,
    pub fetches: u64,
    pub evictions: u64,
    /// ∫ resident_bytes dt over the run.
    pub cache_byte_time: u128,
    pub gpu_capacity: u64,
    /// Whether this worker executed at least one task (Fig. 10 "active").
    pub active: bool,
}

/// Everything an experiment consumes.
#[derive(Debug, Clone, Default)]
pub struct MetricsSink {
    pub jobs: Vec<JobRecord>,
    pub workers: Vec<WorkerMetrics>,
    pub span_us: Micros,
    /// Jobs generated but not completed when the run ended.
    pub incomplete: usize,
    /// Fault-injection counters; all zero unless faults were injected.
    pub faults: FaultStats,
}

impl MetricsSink {
    pub fn slowdowns(&self) -> Vec<f64> {
        self.jobs.iter().filter(|j| !j.failed()).map(|j| j.slowdown()).collect()
    }

    pub fn slowdowns_of(&self, kind: PipelineKind) -> Vec<f64> {
        self.jobs
            .iter()
            .filter(|j| j.kind == kind && !j.failed())
            .map(|j| j.slowdown())
            .collect()
    }

    pub fn mean_latency_s(&self) -> f64 {
        mean(
            &self
                .jobs
                .iter()
                .filter(|j| !j.failed())
                .map(|j| j.latency_us() as f64 / SEC as f64)
                .collect::<Vec<_>>(),
        )
    }

    /// End-to-end latencies (s) of jobs that produced a result, for
    /// percentile reporting (`experiment chaos`).
    pub fn latencies_s(&self) -> Vec<f64> {
        self.jobs
            .iter()
            .filter(|j| !j.failed())
            .map(|j| j.latency_us() as f64 / SEC as f64)
            .collect()
    }

    /// Jobs that terminated `Degraded` (recovered after a fault).
    pub fn degraded_jobs(&self) -> usize {
        self.jobs.iter().filter(|j| j.outcome == JobOutcome::Degraded).count()
    }

    /// Percentage of generated jobs that produced a result: terminal
    /// non-`Failed` records over everything generated (records + jobs
    /// still in flight when the run ended). 100 when nothing ran.
    pub fn completion_rate(&self) -> f64 {
        let generated = self.jobs.len() + self.incomplete;
        if generated == 0 {
            return 100.0;
        }
        let done = self.jobs.iter().filter(|j| !j.failed()).count();
        100.0 * done as f64 / generated as f64
    }

    pub fn mean_slowdown(&self) -> f64 {
        mean(&self.slowdowns())
    }

    pub fn median_slowdown(&self) -> f64 {
        let xs = self.slowdowns();
        if xs.is_empty() {
            return f64::NAN;
        }
        crate::util::stats::median(&xs)
    }

    pub fn box_stats(&self, kind: PipelineKind) -> Option<BoxStats> {
        let xs = self.slowdowns_of(kind);
        if xs.is_empty() {
            None
        } else {
            Some(BoxStats::from(&xs))
        }
    }

    /// Table 1 "GPU utilization (%)": fraction of wall time the GPUs were
    /// executing, averaged over workers that were ever used.
    pub fn gpu_utilization(&self) -> f64 {
        if self.span_us == 0 || self.workers.is_empty() {
            return 0.0;
        }
        let total_busy: u128 = self.workers.iter().map(|w| w.busy_us as u128).sum();
        100.0 * total_busy as f64 / (self.span_us as u128 * self.workers.len() as u128) as f64
    }

    /// Table 1 "GPU memory utilization (%)": time-averaged resident bytes
    /// over capacity. Workers with no GPU memory (capacity 0 — e.g. a
    /// CPU-only ingress node) have no meaningful ratio and are excluded
    /// rather than poisoning the average with inf/NaN.
    pub fn gpu_memory_utilization(&self) -> f64 {
        if self.span_us == 0 {
            return 0.0;
        }
        let with_gpu = self.workers.iter().filter(|w| w.gpu_capacity > 0);
        let (num, n) = with_gpu.fold((0.0f64, 0usize), |(num, n), w| {
            (num + w.cache_byte_time as f64 / (self.span_us as f64 * w.gpu_capacity as f64), n + 1)
        });
        if n == 0 {
            return 0.0;
        }
        100.0 * num / n as f64
    }

    /// Table 1 "GPU energy use (J)" under the linear power model.
    pub fn gpu_energy_joules(&self) -> f64 {
        let span_s = self.span_us as f64 / SEC as f64;
        self.workers
            .iter()
            .map(|w| {
                let busy_s = w.busy_us as f64 / SEC as f64;
                GPU_IDLE_WATTS * span_s + (GPU_ACTIVE_WATTS - GPU_IDLE_WATTS) * busy_s
            })
            .sum()
    }

    /// Table 1 "GPU cache hit rate (%)".
    pub fn cache_hit_rate(&self) -> f64 {
        let hits: u64 = self.workers.iter().map(|w| w.hits).sum();
        let misses: u64 = self.workers.iter().map(|w| w.misses).sum();
        if hits + misses == 0 {
            return 100.0;
        }
        100.0 * hits as f64 / (hits + misses) as f64
    }

    /// Fig. 10: number of workers doing non-negligible work. A worker that
    /// only ever ran glue vertices (10–30 ms each) is effectively idle and
    /// could be put in power-saving mode — the paper's resource claim — so
    /// "active" requires > 0.5% busy time, not merely having run a task.
    pub fn active_workers(&self) -> usize {
        if self.span_us == 0 {
            return 0;
        }
        self.workers
            .iter()
            .filter(|w| w.active && w.busy_us * 200 > self.span_us)
            .count()
    }
}

/// Accumulates busy time for one worker given task start/stop events.
#[derive(Debug, Clone, Copy, Default)]
pub struct BusyTracker {
    busy_us: Micros,
    started_at: Option<Micros>,
}

impl BusyTracker {
    pub fn start(&mut self, now: Micros) {
        debug_assert!(self.started_at.is_none(), "nested busy start");
        self.started_at = Some(now);
    }

    pub fn stop(&mut self, now: Micros) {
        let s = self.started_at.take().expect("stop without start");
        self.busy_us += now - s;
    }

    pub fn total(&self, now: Micros) -> Micros {
        self.busy_us + self.started_at.map(|s| now - s).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{GB, SEC};

    fn record(kind: PipelineKind, lat_s: u64, lb_s: u64) -> JobRecord {
        JobRecord {
            kind,
            arrival_us: 0,
            completion_us: lat_s * SEC,
            lower_bound_us: lb_s * SEC,
            outcome: JobOutcome::Completed,
        }
    }

    #[test]
    fn slowdown_math() {
        let j = record(PipelineKind::Vpa, 6, 2);
        assert_eq!(j.slowdown(), 3.0);
        assert_eq!(j.latency_us(), 6 * SEC);
    }

    #[test]
    fn utilization_and_energy() {
        let sink = MetricsSink {
            jobs: vec![],
            workers: vec![
                WorkerMetrics { busy_us: 5 * SEC, gpu_capacity: 16 * GB, active: true, ..Default::default() },
                WorkerMetrics { busy_us: 0, gpu_capacity: 16 * GB, ..Default::default() },
            ],
            span_us: 10 * SEC,
            incomplete: 0,
            faults: FaultStats::default(),
        };
        assert!((sink.gpu_utilization() - 25.0).abs() < 1e-9);
        // Energy: 2 workers idle 10 s = 200 J, plus 60 W × 5 s active = 300 J.
        assert!((sink.gpu_energy_joules() - 500.0).abs() < 1e-9);
        assert_eq!(sink.active_workers(), 1);
    }

    #[test]
    fn zero_capacity_worker_does_not_poison_memory_utilization() {
        // One real GPU at 50% memory utilization plus one capacity-0 worker
        // (previously a division by zero → inf/NaN for the whole average).
        let sink = MetricsSink {
            workers: vec![
                WorkerMetrics {
                    gpu_capacity: 16 * GB,
                    cache_byte_time: 8 * GB as u128 * (10 * SEC) as u128,
                    ..Default::default()
                },
                WorkerMetrics { gpu_capacity: 0, ..Default::default() },
            ],
            span_us: 10 * SEC,
            ..Default::default()
        };
        let util = sink.gpu_memory_utilization();
        assert!(util.is_finite(), "must not be inf/NaN, got {util}");
        assert!((util - 50.0).abs() < 1e-9, "zero-capacity worker excluded, got {util}");
        // All workers capacity-0 ⇒ defined as 0, not NaN.
        let none = MetricsSink {
            workers: vec![WorkerMetrics::default()],
            span_us: 10 * SEC,
            ..Default::default()
        };
        assert_eq!(none.gpu_memory_utilization(), 0.0);
    }

    #[test]
    fn hit_rate_percent() {
        let sink = MetricsSink {
            workers: vec![WorkerMetrics { hits: 99, misses: 1, ..Default::default() }],
            ..Default::default()
        };
        assert!((sink.cache_hit_rate() - 99.0).abs() < 1e-9);
    }

    #[test]
    fn busy_tracker_accumulates() {
        let mut b = BusyTracker::default();
        b.start(10);
        b.stop(25);
        b.start(30);
        assert_eq!(b.total(40), 25);
    }

    #[test]
    fn failed_jobs_excluded_from_latency_stats() {
        let mut failed = record(PipelineKind::Vpa, 9, 1);
        failed.outcome = JobOutcome::Failed;
        let mut degraded = record(PipelineKind::Vpa, 4, 2);
        degraded.outcome = JobOutcome::Degraded;
        let sink = MetricsSink {
            jobs: vec![record(PipelineKind::Vpa, 2, 1), degraded, failed],
            incomplete: 1,
            ..Default::default()
        };
        // Failed record contributes to neither slowdowns nor latencies.
        assert_eq!(sink.slowdowns(), vec![2.0, 2.0]);
        assert_eq!(sink.latencies_s(), vec![2.0, 4.0]);
        assert_eq!(sink.degraded_jobs(), 1);
        // 2 results over 4 generated (3 records + 1 in flight).
        assert!((sink.completion_rate() - 50.0).abs() < 1e-9);
        // Empty sink is vacuously 100% complete.
        assert_eq!(MetricsSink::default().completion_rate(), 100.0);
    }

    #[test]
    fn per_kind_filtering() {
        let sink = MetricsSink {
            jobs: vec![record(PipelineKind::Vpa, 4, 2), record(PipelineKind::Translation, 3, 1)],
            ..Default::default()
        };
        assert_eq!(sink.slowdowns_of(PipelineKind::Vpa), vec![2.0]);
        assert_eq!(sink.mean_slowdown(), 2.5);
    }
}
