//! Text format for user-defined workflows.
//!
//! Downstream users are not limited to the four Figure-1 pipelines: a DFG
//! can be described in a small line-oriented format and scheduled like any
//! built-in workflow.
//!
//! ```text
//! # my-pipeline.dfg
//! pipeline my-pipeline          # header (name; kind slot is assigned)
//! task detect   model=detr   runtime_ms=300 output_kb=50
//! task caption  model=vit-gpt2 runtime_ms=250 output_kb=2
//! task fuse     runtime_ms=20 output_kb=4    # no model => host glue
//! edge detect -> fuse
//! edge caption -> fuse
//! ```
//!
//! Tasks without an incoming edge hang off an implicit entry; the format
//! requires exactly one entry and one exit (as the core `Dfg` does).

use super::models::MODELS;
use super::{Dfg, PipelineKind, Vertex};
use crate::core::{Micros, KB, MS};
use crate::net::CostModel;
use anyhow::{anyhow, bail, Result};

/// Parse a `.dfg` document into a `Dfg`. `kind` assigns the pipeline slot
/// (user DFGs typically reuse one of the four kind slots for metrics).
pub fn parse_dfg(src: &str, kind: PipelineKind, cost: &CostModel) -> Result<Dfg> {
    let mut names: Vec<String> = Vec::new();
    let mut vertices: Vec<Vertex> = Vec::new();
    let mut edges: Vec<(usize, usize)> = Vec::new();
    let mut pipeline_name = None;

    for (lineno, raw) in src.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("pipeline") => {
                pipeline_name =
                    Some(parts.next().ok_or_else(|| anyhow!("line {}: pipeline needs a name", lineno + 1))?.to_string());
            }
            Some("task") => {
                let name = parts
                    .next()
                    .ok_or_else(|| anyhow!("line {}: task needs a name", lineno + 1))?
                    .to_string();
                if names.contains(&name) {
                    bail!("line {}: duplicate task '{name}'", lineno + 1);
                }
                let mut model = None;
                let mut runtime: Micros = 100 * MS;
                let mut output: u64 = KB;
                for kv in parts {
                    let (k, v) = kv
                        .split_once('=')
                        .ok_or_else(|| anyhow!("line {}: expected key=value, got '{kv}'", lineno + 1))?;
                    match k {
                        "model" => {
                            let m = MODELS
                                .iter()
                                .find(|m| m.name == v || m.artifact == v)
                                .ok_or_else(|| anyhow!("line {}: unknown model '{v}'", lineno + 1))?;
                            model = Some(m.id);
                        }
                        "runtime_ms" => runtime = v.parse::<u64>()? * MS,
                        "output_kb" => output = v.parse::<u64>()? * KB,
                        other => bail!("line {}: unknown attribute '{other}'", lineno + 1),
                    }
                }
                let id = vertices.len();
                names.push(name);
                vertices.push(Vertex {
                    id,
                    // Vertex names are &'static str for the built-ins;
                    // user tasks get leaked once per parse (DFGs are small,
                    // static, loaded once — paper §2.2).
                    name: Box::leak(names.last().unwrap().clone().into_boxed_str()),
                    model,
                    mean_runtime_us: runtime,
                    output_bytes: output,
                });
            }
            Some("edge") => {
                let from = parts.next().ok_or_else(|| anyhow!("line {}: edge needs 'a -> b'", lineno + 1))?;
                let arrow = parts.next();
                let to = parts.next();
                if arrow != Some("->") || to.is_none() {
                    bail!("line {}: edge syntax is 'edge a -> b'", lineno + 1);
                }
                let fi = names
                    .iter()
                    .position(|n| n == from)
                    .ok_or_else(|| anyhow!("line {}: unknown task '{from}'", lineno + 1))?;
                let ti = names
                    .iter()
                    .position(|n| n == to.unwrap())
                    .ok_or_else(|| anyhow!("line {}: unknown task '{}'", lineno + 1, to.unwrap()))?;
                edges.push((fi, ti));
            }
            Some(other) => bail!("line {}: unknown directive '{other}'", lineno + 1),
            None => unreachable!(),
        }
    }

    if pipeline_name.is_none() {
        bail!("missing 'pipeline <name>' header");
    }
    if vertices.is_empty() {
        bail!("no tasks defined");
    }
    // Dfg::new validates single entry/exit and acyclicity.
    let n = vertices.len();
    let has_pred: Vec<bool> = (0..n).map(|v| edges.iter().any(|&(_, b)| b == v)).collect();
    let has_succ: Vec<bool> = (0..n).map(|v| edges.iter().any(|&(a, _)| a == v)).collect();
    if (0..n).filter(|&v| !has_pred[v]).count() != 1 {
        bail!("exactly one entry task required");
    }
    if (0..n).filter(|&v| !has_succ[v]).count() != 1 {
        bail!("exactly one exit task required");
    }
    Ok(Dfg::new(kind, vertices, &edges, cost))
}

/// Parse from a file path.
pub fn parse_dfg_file(
    path: &std::path::Path,
    kind: PipelineKind,
    cost: &CostModel,
) -> Result<Dfg> {
    parse_dfg(&std::fs::read_to_string(path)?, kind, cost)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = "\
# demo
pipeline demo
task detect  model=detr runtime_ms=300 output_kb=50
task depth   model=glpn-depth runtime_ms=350 output_kb=1000
task ingress runtime_ms=10 output_kb=300
task fuse    runtime_ms=30 output_kb=100
edge ingress -> detect
edge ingress -> depth
edge detect -> fuse
edge depth -> fuse
";

    #[test]
    fn parses_perception_like_pipeline() {
        let d = parse_dfg(DOC, PipelineKind::Perception, &CostModel::default()).unwrap();
        assert_eq!(d.len(), 4);
        assert_eq!(d.vertices[0].name, "detect");
        assert_eq!(d.vertices[0].model, Some(super::super::models::DETR));
        assert!(d.is_join(3));
        assert_eq!(d.entry, 2);
        assert_eq!(d.exit, 3);
        assert_eq!(d.lower_bound_us, (10 + 350 + 30) * MS);
    }

    #[test]
    fn parsed_dfg_is_schedulable() {
        use crate::config::ClusterConfig;
        use crate::sched::{self, ClusterView};
        use crate::sst::SstRow;
        let d = parse_dfg(DOC, PipelineKind::Perception, &CostModel::default()).unwrap();
        let cfg = ClusterConfig::default();
        let sched = sched::build(&cfg);
        let cost = CostModel::default();
        let rows = vec![SstRow::default(); 5];
        let speed = vec![1.0; 5];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &sched::PlanCell::default(),
        };
        let job = crate::dfg::Job {
            id: 1,
            kind: PipelineKind::Perception,
            arrival_us: 0,
            input_bytes: 1000,
        };
        let adfg = sched.plan(&job, &d, &view);
        assert!(adfg.assignment.iter().all(|a| a.is_some()));
    }

    #[test]
    fn rejects_unknown_model() {
        let err = parse_dfg(
            "pipeline x\ntask a model=nope runtime_ms=1\n",
            PipelineKind::Vpa,
            &CostModel::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown model"));
    }

    #[test]
    fn rejects_bad_edges_and_cycles() {
        assert!(parse_dfg(
            "pipeline x\ntask a\ntask b\nedge a -> c\n",
            PipelineKind::Vpa,
            &CostModel::default()
        )
        .is_err());
        assert!(parse_dfg(
            "pipeline x\ntask a\ntask b\nedge a b\n",
            PipelineKind::Vpa,
            &CostModel::default()
        )
        .is_err());
    }

    #[test]
    fn rejects_multi_entry() {
        let err = parse_dfg(
            "pipeline x\ntask a\ntask b\ntask c\nedge a -> c\nedge b -> c\n",
            PipelineKind::Vpa,
            &CostModel::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("one entry"), "{err}");
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let d = parse_dfg(
            "# hi\n\npipeline x  # trailing\ntask only runtime_ms=5\n",
            PipelineKind::Vpa,
            &CostModel::default(),
        )
        .unwrap();
        assert_eq!(d.len(), 1);
    }
}
