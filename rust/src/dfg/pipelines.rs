//! The four workflows of Figure 1, with profiled parameters.
//!
//! Runtimes are set so that idle-cluster completion times land in the
//! paper's 1–3 s band for the two "long" pipelines (translation, VPA) and
//! well under 1 s for the two "short" ones (image caption, 3D perception) —
//! §6.2.2 attributes the short pipelines' extreme slow-down factors under
//! load to their short runtimes. Output sizes model text (KBs) vs.
//! image/feature tensors (100s of KB–MBs).

use super::models::*;
use super::{Dfg, PipelineKind, Vertex};
use crate::core::{Micros, KB, MB, MS};
use crate::net::CostModel;

fn v(id: usize, name: &'static str, model: Option<u8>, rt_ms: Micros, out: u64) -> Vertex {
    Vertex { id, name, model, mean_runtime_us: rt_ms * MS, output_bytes: out }
}

/// Figure 1a — multilingual meeting auto-captioning.
/// opt → {marian(fr), mt5(zh), mt5(ja)} → aggregate.
pub fn translation(cost: &CostModel) -> Dfg {
    Dfg::new(
        PipelineKind::Translation,
        vec![
            v(0, "opt-understand", Some(OPT), 800, 8 * KB),
            v(1, "marian-fr", Some(MARIAN), 500, 4 * KB),
            v(2, "mt5-zh", Some(MT5), 600, 4 * KB),
            v(3, "mt5-ja", Some(MT5), 600, 4 * KB),
            v(4, "aggregate", None, 20, 12 * KB),
        ],
        &[(0, 1), (0, 2), (0, 3), (1, 4), (2, 4), (3, 4)],
        cost,
    )
}

/// Figure 1b — image captioning for children's education.
/// vit-gpt2(caption) → bart(child-safety) → espnet(vocalize).
pub fn image_caption(cost: &CostModel) -> Dfg {
    Dfg::new(
        PipelineKind::ImageCaption,
        vec![
            v(0, "vit-gpt2-caption", Some(VIT_GPT2), 250, 2 * KB),
            v(1, "bart-child-safe", Some(BART), 200, 2 * KB),
            v(2, "espnet-vocalize", Some(ESPNET), 250, 400 * KB),
        ],
        &[(0, 1), (1, 2)],
        cost,
    )
}

/// Figure 1c — virtual personal assistant Q&A.
/// opt(prompted) → bart(adult shaping) → respond.
pub fn vpa(cost: &CostModel) -> Dfg {
    Dfg::new(
        PipelineKind::Vpa,
        vec![
            v(0, "opt-dialogue", Some(OPT), 1200, 8 * KB),
            v(1, "bart-shape", Some(BART), 400, 4 * KB),
            v(2, "respond", None, 10, 4 * KB),
        ],
        &[(0, 1), (1, 2)],
        cost,
    )
}

/// Figure 1d — 3D perception for a vision-impaired user.
/// ingress → {detr(objects), glpn(depth)} → combine.
pub fn perception(cost: &CostModel) -> Dfg {
    Dfg::new(
        PipelineKind::Perception,
        vec![
            v(0, "ingress", None, 10, 300 * KB),
            v(1, "detr-objects", Some(DETR), 300, 50 * KB),
            v(2, "glpn-depth", Some(GLPN), 350, 1 * MB),
            v(3, "combine", None, 30, 100 * KB),
        ],
        &[(0, 1), (0, 2), (1, 3), (2, 3)],
        cost,
    )
}

/// All four pipelines, indexed by `PipelineKind::index()`.
pub fn all(cost: &CostModel) -> Vec<Dfg> {
    vec![translation(cost), image_caption(cost), vpa(cost), perception(cost)]
}

pub fn by_kind(kind: PipelineKind, cost: &CostModel) -> Dfg {
    match kind {
        PipelineKind::Translation => translation(cost),
        PipelineKind::ImageCaption => image_caption(cost),
        PipelineKind::Vpa => vpa(cost),
        PipelineKind::Perception => perception(cost),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::SEC;

    #[test]
    fn four_pipelines_kinds_match_index() {
        let all = all(&CostModel::default());
        assert_eq!(all.len(), 4);
        for (i, d) in all.iter().enumerate() {
            assert_eq!(d.kind.index(), i);
        }
    }

    #[test]
    fn long_pipelines_in_1_to_3s_band() {
        // §6: "On an idle system with ML models cached in GPU, the average
        // completion times would range from 1 to 3 seconds."
        let c = CostModel::default();
        assert!((SEC..=3 * SEC).contains(&translation(&c).lower_bound_us));
        assert!((SEC..=3 * SEC).contains(&vpa(&c).lower_bound_us));
    }

    #[test]
    fn short_pipelines_are_short() {
        // §6.2.2: image description and 3D perception have "relatively short
        // runtimes", making them overhead-sensitive.
        let c = CostModel::default();
        assert!(image_caption(&c).lower_bound_us < SEC);
        assert!(perception(&c).lower_bound_us < SEC);
    }

    #[test]
    fn translation_reuses_mt5_for_two_languages() {
        // Figure 1a: mt5 plays two roles but is a single model.
        let d = translation(&CostModel::default());
        let mt5_uses = d.vertices.iter().filter(|v| v.model == Some(MT5)).count();
        assert_eq!(mt5_uses, 2);
    }

    #[test]
    fn perception_has_parallel_branches_and_join() {
        let d = perception(&CostModel::default());
        assert_eq!(d.succs[d.entry].len(), 2);
        assert!(d.is_join(d.exit));
    }

    #[test]
    fn glue_vertices_have_no_model() {
        for d in all(&CostModel::default()) {
            for t in &d.vertices {
                if t.model.is_none() {
                    assert!(t.mean_runtime_us <= 50 * MS, "{} too heavy for glue", t.name);
                }
            }
        }
    }
}
