//! Dataflow-graph representation of ML workflows (paper §2.1).
//!
//! A `Dfg` is a directed acyclic graph whose vertices are ML computations,
//! each annotated with the model it depends on (the paper's "diamond box"),
//! the profiled mean runtime, and output-object size. Edges are precedence
//! constraints. `compute_ranks` implements the HEFT-style upward ranking of
//! Eq. 1; `lower_bound_us` is the §6.1 latency lower bound (maximum task
//! parallelism, zero transfer delay, all models GPU-resident).

pub mod models;
pub mod parse;
pub mod pipelines;

use crate::core::{JobId, Micros, ModelId, TaskId, WorkerId};
use crate::net::CostModel;

/// One ML computation in a workflow.
#[derive(Debug, Clone)]
pub struct Vertex {
    pub id: TaskId,
    pub name: &'static str,
    /// Model dependency (None for trivial glue vertices: ingress, join,
    /// aggregate — these run on the host, no GPU model required).
    pub model: Option<ModelId>,
    /// Profiled mean runtime on a reference worker, µs (paper: from the
    /// Workflow Profiles Repository, covering ≥95% of observed runs).
    pub mean_runtime_us: Micros,
    /// Profiled output object size |output_t| in bytes.
    pub output_bytes: u64,
}

/// The four pipeline types of Figure 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PipelineKind {
    /// 1a — multilingual meeting auto-caption (OPT → Marian/mT5×2 → agg).
    Translation,
    /// 1b — child-education image captioning (ViT-GPT2 → BART → ESPnet).
    ImageCaption,
    /// 1c — virtual personal assistant Q&A (OPT → BART).
    Vpa,
    /// 1d — vision-impaired assistance (DETR ∥ GLPN → combine).
    Perception,
}

impl PipelineKind {
    pub const ALL: [PipelineKind; 4] = [
        PipelineKind::Translation,
        PipelineKind::ImageCaption,
        PipelineKind::Vpa,
        PipelineKind::Perception,
    ];

    pub fn index(self) -> usize {
        match self {
            PipelineKind::Translation => 0,
            PipelineKind::ImageCaption => 1,
            PipelineKind::Vpa => 2,
            PipelineKind::Perception => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PipelineKind::Translation => "translation",
            PipelineKind::ImageCaption => "image-caption",
            PipelineKind::Vpa => "vpa-qa",
            PipelineKind::Perception => "3d-perception",
        }
    }

    pub fn from_index(i: usize) -> PipelineKind {
        PipelineKind::ALL[i]
    }
}

/// A workflow DAG plus everything derived statically from it.
#[derive(Debug, Clone)]
pub struct Dfg {
    pub kind: PipelineKind,
    pub vertices: Vec<Vertex>,
    pub preds: Vec<Vec<TaskId>>,
    pub succs: Vec<Vec<TaskId>>,
    pub entry: TaskId,
    pub exit: TaskId,
    /// Upward ranks (Eq. 1), µs — computed once at load (paper §4.2.1).
    pub ranks: Vec<f64>,
    /// Task ids in descending-rank order, cached at load (planning runs on
    /// the request path once per job; re-sorting there is wasted work).
    rank_order: Vec<TaskId>,
    /// §6.1 latency lower bound, µs.
    pub lower_bound_us: Micros,
}

impl Dfg {
    /// Build a DFG from vertices and edges, computing static ranks with the
    /// given cost model (Eq. 1 uses TD_output in ranking).
    pub fn new(
        kind: PipelineKind,
        vertices: Vec<Vertex>,
        edges: &[(TaskId, TaskId)],
        cost: &CostModel,
    ) -> Dfg {
        let n = vertices.len();
        let mut preds = vec![Vec::new(); n];
        let mut succs = vec![Vec::new(); n];
        for &(a, b) in edges {
            assert!(a < n && b < n && a != b, "bad edge ({a},{b})");
            succs[a].push(b);
            preds[b].push(a);
        }
        let entry = (0..n)
            .find(|&v| preds[v].is_empty())
            .expect("DFG must have an entry vertex");
        let exit = (0..n)
            .find(|&v| succs[v].is_empty())
            .expect("DFG must have an exit vertex");
        assert_eq!(
            (0..n).filter(|&v| preds[v].is_empty()).count(),
            1,
            "single entry required"
        );
        assert_eq!(
            (0..n).filter(|&v| succs[v].is_empty()).count(),
            1,
            "single exit required"
        );

        let mut dfg = Dfg {
            kind,
            vertices,
            preds,
            succs,
            entry,
            exit,
            ranks: Vec::new(),
            rank_order: Vec::new(),
            lower_bound_us: 0,
        };
        dfg.assert_acyclic();
        dfg.ranks = dfg.compute_ranks(cost);
        dfg.rank_order = dfg.compute_rank_order();
        dfg.lower_bound_us = dfg.critical_path_us();
        dfg
    }

    pub fn len(&self) -> usize {
        self.vertices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vertices.is_empty()
    }

    /// A join task has >1 predecessor; it cannot be dynamically re-placed
    /// (Algorithm 2, line 3) because its predecessors coordinated on it.
    pub fn is_join(&self, t: TaskId) -> bool {
        self.preds[t].len() > 1
    }

    fn assert_acyclic(&self) {
        // Kahn's algorithm; panics if edges form a cycle.
        let n = self.len();
        let mut indeg: Vec<usize> = (0..n).map(|v| self.preds[v].len()).collect();
        let mut stack: Vec<TaskId> = (0..n).filter(|&v| indeg[v] == 0).collect();
        let mut seen = 0;
        while let Some(v) = stack.pop() {
            seen += 1;
            for &s in &self.succs[v] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    stack.push(s);
                }
            }
        }
        assert_eq!(seen, n, "DFG contains a cycle");
    }

    /// Topological order (entry first).
    pub fn topo_order(&self) -> Vec<TaskId> {
        let n = self.len();
        let mut indeg: Vec<usize> = (0..n).map(|v| self.preds[v].len()).collect();
        let mut stack: Vec<TaskId> = (0..n).filter(|&v| indeg[v] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(v) = stack.pop() {
            order.push(v);
            for &s in &self.succs[v] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    stack.push(s);
                }
            }
        }
        order
    }

    /// Eq. 1: rank(t) = R(t) + max_{t≺t'} (TD_output(t) + rank(t')).
    /// R(t) here is the reference mean (workers unknown at rank time).
    fn compute_ranks(&self, cost: &CostModel) -> Vec<f64> {
        let mut ranks = vec![0.0f64; self.len()];
        let order = self.topo_order();
        for &t in order.iter().rev() {
            let td_out = cost.td_transfer(self.vertices[t].output_bytes) as f64;
            let tail = self.succs[t]
                .iter()
                .map(|&s| td_out + ranks[s])
                .fold(0.0f64, f64::max);
            ranks[t] = self.vertices[t].mean_runtime_us as f64 + tail;
        }
        ranks
    }

    /// Critical path by runtime only — zero transfer, all models cached:
    /// the §6.1 lower bound for the slowdown factor.
    fn critical_path_us(&self) -> Micros {
        let mut lb = vec![0u64; self.len()];
        let order = self.topo_order();
        for &t in order.iter().rev() {
            let tail = self.succs[t].iter().map(|&s| lb[s]).max().unwrap_or(0);
            lb[t] = self.vertices[t].mean_runtime_us + tail;
        }
        lb[self.entry]
    }

    /// Task ids in descending-rank order (planning order, §4.2.2); ties
    /// break by id (paper: by arrival — ids encode DFG order). Cached.
    pub fn rank_order(&self) -> &[TaskId] {
        &self.rank_order
    }

    fn compute_rank_order(&self) -> Vec<TaskId> {
        let mut ids: Vec<TaskId> = (0..self.len()).collect();
        ids.sort_by(|&a, &b| {
            crate::util::stats::cmp_f64(self.ranks[b], self.ranks[a]).then(a.cmp(&b))
        });
        ids
    }

    /// Total |input_t| in bytes for a task: sum of predecessor outputs
    /// (entry tasks consume the client input, accounted separately).
    pub fn input_bytes(&self, t: TaskId) -> u64 {
        self.preds[t]
            .iter()
            .map(|&p| self.vertices[p].output_bytes)
            .sum()
    }
}

/// One triggered job instance (a request flowing through one DFG).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub kind: PipelineKind,
    /// Arrival (generation) time at the cluster, µs.
    pub arrival_us: Micros,
    /// Client input object size in bytes (GLUE text / COCO image sample).
    pub input_bytes: u64,
}

/// Activated DFG: the per-job worker-assignment map (paper §3).
/// Piggybacked task-to-task as the job executes; entries start as the
/// planning phase's choices and may be rewritten by dynamic adjustment.
#[derive(Debug, Clone)]
pub struct Adfg {
    pub assignment: Vec<Option<WorkerId>>,
}

impl Adfg {
    pub fn unassigned(n: usize) -> Adfg {
        Adfg { assignment: vec![None; n] }
    }

    pub fn get(&self, t: TaskId) -> Option<WorkerId> {
        self.assignment[t]
    }

    pub fn set(&mut self, t: TaskId, w: WorkerId) {
        self.assignment[t] = Some(w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{KB, MS};

    fn diamond() -> Dfg {
        // 0 -> {1, 2} -> 3
        let v = |id, rt: Micros, out| Vertex {
            id,
            name: "t",
            model: None,
            mean_runtime_us: rt,
            output_bytes: out,
        };
        Dfg::new(
            PipelineKind::Perception,
            vec![v(0, 10 * MS, KB), v(1, 300 * MS, KB), v(2, 350 * MS, KB), v(3, 30 * MS, KB)],
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            &CostModel::default(),
        )
    }

    #[test]
    fn entry_exit_detected() {
        let d = diamond();
        assert_eq!(d.entry, 0);
        assert_eq!(d.exit, 3);
        assert!(d.is_join(3));
        assert!(!d.is_join(1));
    }

    #[test]
    fn ranks_decrease_along_edges() {
        let d = diamond();
        for t in 0..d.len() {
            for &s in &d.succs[t] {
                assert!(d.ranks[t] > d.ranks[s], "rank({t}) !> rank({s})");
            }
        }
    }

    #[test]
    fn rank_order_starts_at_entry() {
        let d = diamond();
        assert_eq!(d.rank_order()[0], d.entry);
        assert_eq!(*d.rank_order().last().unwrap(), d.exit);
    }

    #[test]
    fn lower_bound_is_critical_path() {
        let d = diamond();
        // 10 + max(300, 350) + 30 = 390 ms.
        assert_eq!(d.lower_bound_us, 390 * MS);
    }

    #[test]
    fn input_bytes_sums_pred_outputs() {
        let d = diamond();
        assert_eq!(d.input_bytes(3), 2 * KB);
        assert_eq!(d.input_bytes(0), 0);
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_rejected() {
        let v = |id| Vertex { id, name: "t", model: None, mean_runtime_us: 1, output_bytes: 0 };
        // 1 -> 2 -> 1 cycle behind entry 0 and exit 3.
        Dfg::new(
            PipelineKind::Vpa,
            vec![v(0), v(1), v(2), v(3)],
            &[(0, 1), (1, 2), (2, 1), (2, 3)],
            &CostModel::default(),
        );
    }

    #[test]
    fn topo_order_respects_edges() {
        let d = diamond();
        let order = d.topo_order();
        let pos: Vec<usize> = (0..d.len()).map(|t| order.iter().position(|&x| x == t).unwrap()).collect();
        for t in 0..d.len() {
            for &s in &d.succs[t] {
                assert!(pos[t] < pos[s]);
            }
        }
    }
}
