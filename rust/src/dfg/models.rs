//! The model table: the eight ML models used by the paper's four pipelines
//! (Figure 1), with their *profiled* sizes.
//!
//! Sizes follow the paper's §2.2: each model is several GB and the set
//! aggregates to ~35 GB — more than double a 16 GB GPU. `artifact` names the
//! AOT-compiled tiny-transformer HLO that the live runtime executes for
//! vertices bound to this model (see python/compile/model.py; the scheduler
//! itself only ever consumes the profiled numbers here).

use crate::core::{ModelId, GB};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelInfo {
    pub id: ModelId,
    pub name: &'static str,
    /// Profiled (paper-scale) GPU memory footprint of the decompressed model.
    pub mem_bytes: u64,
    /// AOT artifact base name under artifacts/ (`<artifact>.hlo.txt`).
    pub artifact: &'static str,
    /// Batch cost-curve exponent: a batch of b instances runs in
    /// `R · (batch_alpha + (1 - batch_alpha) · b)`. Lower = more
    /// batch-friendly (encoder-style models amortize better than
    /// autoregressive decoders).
    pub batch_alpha: f64,
}

pub const N_MODELS: usize = 8;

/// ids must match python/compile/model.py MODEL_SPECS.
pub const MODELS: [ModelInfo; N_MODELS] = [
    ModelInfo { id: 0, name: "opt-1.3b", mem_bytes: 6 * GB, artifact: "opt", batch_alpha: 0.70 },
    ModelInfo { id: 1, name: "marian", mem_bytes: 3 * GB, artifact: "marian", batch_alpha: 0.60 },
    ModelInfo { id: 2, name: "mt5", mem_bytes: 5 * GB, artifact: "mt5", batch_alpha: 0.65 },
    ModelInfo { id: 3, name: "vit-gpt2", mem_bytes: 4 * GB, artifact: "vit_gpt2", batch_alpha: 0.55 },
    ModelInfo { id: 4, name: "espnet", mem_bytes: 3 * GB, artifact: "espnet", batch_alpha: 0.60 },
    ModelInfo { id: 5, name: "bart", mem_bytes: 5 * GB, artifact: "bart", batch_alpha: 0.65 },
    ModelInfo { id: 6, name: "detr", mem_bytes: 4 * GB, artifact: "detr", batch_alpha: 0.50 },
    ModelInfo { id: 7, name: "glpn-depth", mem_bytes: 5 * GB, artifact: "glpn", batch_alpha: 0.50 },
];

pub const OPT: ModelId = 0;
pub const MARIAN: ModelId = 1;
pub const MT5: ModelId = 2;
pub const VIT_GPT2: ModelId = 3;
pub const ESPNET: ModelId = 4;
pub const BART: ModelId = 5;
pub const DETR: ModelId = 6;
pub const GLPN: ModelId = 7;

#[inline]
pub fn model(id: ModelId) -> &'static ModelInfo {
    &MODELS[id as usize]
}

#[inline]
pub fn model_bytes(id: ModelId) -> u64 {
    MODELS[id as usize].mem_bytes
}

/// Mean model size — used for the scheduler's eviction-penalty estimate.
pub fn mean_model_bytes() -> u64 {
    MODELS.iter().map(|m| m.mem_bytes).sum::<u64>() / MODELS.len() as u64
}

/// Profiled batch cost-curve alpha for a model.
#[inline]
pub fn batch_alpha(id: ModelId) -> f64 {
    MODELS[id as usize].batch_alpha
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_dense_and_ordered() {
        for (i, m) in MODELS.iter().enumerate() {
            assert_eq!(m.id as usize, i);
        }
    }

    #[test]
    fn aggregate_is_paper_scale() {
        // §2.2: "total memory aggregated over the full set of DFGs is nearly
        // 35GB, which already exceeds what a single standard cloud GPU holds".
        let total: u64 = MODELS.iter().map(|m| m.mem_bytes).sum();
        assert_eq!(total, 35 * GB);
        assert!(MODELS.iter().all(|m| m.mem_bytes > 16 * GB / 8));
    }

    #[test]
    fn all_fit_bitmap_id_space() {
        // §5.2: 64-bit bitmap encoding limits active models to ids 0..63.
        assert!(MODELS.iter().all(|m| m.id < 64));
    }

    #[test]
    fn batch_alphas_are_sublinear_fractions() {
        // alpha ∈ (0, 1): a batch is cheaper than serial (alpha < 1) but
        // never cheaper than one instance (alpha > 0).
        for m in MODELS.iter() {
            assert!(m.batch_alpha > 0.0 && m.batch_alpha < 1.0, "{}", m.name);
        }
        assert_eq!(batch_alpha(DETR), 0.50);
    }
}
