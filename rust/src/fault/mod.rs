//! Deterministic fault injection and recovery policy (DESIGN.md §9).
//!
//! Compass is a *decentralized* scheduler, so its failure story has no
//! central coordinator either: every worker watches the same SST rows it
//! already receives for scheduling, declares a peer dead when that peer's
//! row goes stale past a threshold (missed heartbeats), poisons the row so
//! all four schedulers mask the worker out, and re-places the orphaned
//! tasks through the ordinary Algorithm 1/2 machinery.
//!
//! Everything here is policy and plumbing shared by both execution paths:
//!
//! * [`FaultConfig`] — the config/CLI-facing knobs (crash rate or explicit
//!   `w@ms` crashes, transient slowdown, message drop/delay, model-fetch
//!   failure, retry/backoff, heartbeat staleness threshold, fault seed).
//! * [`FaultPlan`] — the *materialized* schedule of worker crashes and
//!   slowdown windows, sampled once up front from a dedicated SplitMix64
//!   stream so a plan is a pure function of `(FaultConfig, n_workers)`:
//!   the simulator turns it into first-class events, the live cluster
//!   hands each worker thread its own crash time.
//! * [`NetFaults`] — the message drop/delay shim consumed by
//!   `coordinator::network::run_fabric_faults`.
//!
//! Determinism contract: the fault streams are seeded independently of the
//! workload seed (`seed ^ 0xFA01` for the plan, `^ 0xFA02` / `^ 0xFA03`
//! for the online sim/fabric draws), and a *disabled* config draws nothing
//! at all — an empty plan leaves the simulator byte-identical to the
//! failure-free build (locked by `tests/prop_faults.rs`).

use crate::core::{Micros, WorkerId, MS, SEC};
use crate::util::args::Args;
use crate::util::rng::Rng;

/// Bounded-retry policy for transient failures (model fetch today; any
/// retryable step tomorrow). Exponential backoff: attempt `a` waits
/// `backoff_base_us << a` before trying again.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryConfig {
    /// Total attempts including the first (so 3 = one try + two retries).
    pub max_attempts: u32,
    /// Backoff before retry 1; doubles per further attempt.
    pub backoff_base_us: Micros,
}

impl Default for RetryConfig {
    fn default() -> RetryConfig {
        RetryConfig { max_attempts: 3, backoff_base_us: 50 * MS }
    }
}

impl RetryConfig {
    /// Backoff to wait after failed attempt `attempt` (0-based).
    #[inline]
    pub fn backoff_us(&self, attempt: u32) -> Micros {
        self.backoff_base_us.saturating_mul(1u64 << attempt.min(16))
    }
}

/// All fault-injection knobs. The default is fully disabled: every rate is
/// zero and no explicit crash is listed, which the rest of the system takes
/// as "inject nothing, draw nothing, change nothing".
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Per-worker probability of one crash inside `[0, crash_window_us)`.
    pub crash_rate: f64,
    /// Explicit crashes `(worker, at_us)`, unioned with the sampled set
    /// (earliest time wins if both name the same worker).
    pub crashes: Vec<(WorkerId, Micros)>,
    /// Window in which sampled crash times fall.
    pub crash_window_us: Micros,
    /// Per-worker probability of one transient slowdown window.
    pub slowdown_rate: f64,
    /// Runtime multiplier while a slowdown window is active (> 1).
    pub slowdown_factor: f64,
    /// Length of a slowdown window.
    pub slowdown_us: Micros,
    /// Probability a fabric message is "dropped". Transport is reliable
    /// (in-process channels), so a drop is modeled as the retransmit it
    /// would trigger: the message arrives late, never never-arrives.
    pub drop_prob: f64,
    /// Probability a fabric message is delayed by `delay_us`.
    pub delay_prob: f64,
    /// Extra latency charged to a delayed message.
    pub delay_us: Micros,
    /// Per-attempt probability a model fetch fails transiently.
    pub fetch_fail_prob: f64,
    /// Bounded retry + exponential backoff for transient failures.
    pub retry: RetryConfig,
    /// A worker whose SST row is staler than this is declared dead
    /// (heartbeats ride the existing SST pushes; see DESIGN.md §9).
    pub heartbeat_timeout_us: Micros,
    /// Fault-stream seed, independent of the workload seed.
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig {
            crash_rate: 0.0,
            crashes: Vec::new(),
            crash_window_us: 20 * SEC,
            slowdown_rate: 0.0,
            slowdown_factor: 3.0,
            slowdown_us: 2 * SEC,
            drop_prob: 0.0,
            delay_prob: 0.0,
            delay_us: 20 * MS,
            fetch_fail_prob: 0.0,
            retry: RetryConfig::default(),
            // Three missed 200 ms SST pushes.
            heartbeat_timeout_us: 600 * MS,
            seed: 0xFA17,
        }
    }
}

impl FaultConfig {
    /// Any injection at all? When false the whole subsystem must be inert:
    /// no RNG draws, no events, no extra branches taken.
    pub fn enabled(&self) -> bool {
        self.crash_rate > 0.0
            || !self.crashes.is_empty()
            || self.slowdown_rate > 0.0
            || self.net_enabled()
            || self.fetch_fail_prob > 0.0
    }

    /// Any fabric-level fault (drop/delay)?
    pub fn net_enabled(&self) -> bool {
        self.drop_prob > 0.0 || self.delay_prob > 0.0
    }

    /// Build the fabric injection shim, if fabric faults are configured.
    pub fn net_faults(&self) -> Option<NetFaults> {
        if !self.net_enabled() {
            return None;
        }
        Some(NetFaults {
            drop_prob: self.drop_prob,
            delay_prob: self.delay_prob,
            delay_us: self.delay_us,
            retransmit_us: self.retry.backoff_base_us,
            rng: Rng::new(self.seed ^ 0xFA03),
        })
    }
}

/// One transient slowdown window: runtimes on the worker are multiplied by
/// `factor` while `start_us <= now < end_us`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlowdownWindow {
    pub start_us: Micros,
    pub end_us: Micros,
    pub factor: f64,
}

/// The materialized fault schedule: what will actually happen, per worker.
/// A pure function of `(FaultConfig, n_workers)` — both execution paths
/// materialize the same plan and therefore kill the same workers at the
/// same (virtual) times.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Per-worker crash time; `None` = survives the run.
    pub crash_at: Vec<Option<Micros>>,
    /// Per-worker slowdown window, if any.
    pub slowdowns: Vec<Option<SlowdownWindow>>,
}

impl FaultPlan {
    /// An empty plan for `n` workers (nothing ever happens).
    pub fn none(n: usize) -> FaultPlan {
        FaultPlan { crash_at: vec![None; n], slowdowns: vec![None; n] }
    }

    /// Sample the plan from the config's dedicated fault stream. Every
    /// worker consumes a fixed number of draws regardless of outcome, so
    /// nudging one rate never reshuffles another worker's fate.
    pub fn materialize(cfg: &FaultConfig, n_workers: usize) -> FaultPlan {
        let mut plan = FaultPlan::none(n_workers);
        if !cfg.enabled() {
            return plan;
        }
        let mut rng = Rng::new(cfg.seed ^ 0xFA01);
        for w in 0..n_workers {
            let (crash_roll, crash_frac) = (rng.f64(), rng.f64());
            if cfg.crash_rate > 0.0 && crash_roll < cfg.crash_rate {
                plan.crash_at[w] = Some((crash_frac * cfg.crash_window_us as f64) as Micros);
            }
            let (slow_roll, slow_frac) = (rng.f64(), rng.f64());
            if cfg.slowdown_rate > 0.0 && slow_roll < cfg.slowdown_rate {
                let start = (slow_frac * cfg.crash_window_us as f64) as Micros;
                plan.slowdowns[w] = Some(SlowdownWindow {
                    start_us: start,
                    end_us: start + cfg.slowdown_us,
                    factor: cfg.slowdown_factor,
                });
            }
        }
        // Safety valve on the *sampled* set: a high crash rate must not
        // silently kill the whole cluster. Spare the latest crasher so at
        // least one worker survives to detect and finish. Explicit `w@ms`
        // crashes are applied afterwards and may still kill everyone —
        // that is how the `Failed` outcome path is exercised.
        if n_workers > 0 && plan.crash_at.iter().all(|c| c.is_some()) {
            let last = (0..n_workers)
                .max_by_key(|&w| plan.crash_at[w].unwrap_or(0))
                .unwrap_or(0);
            plan.crash_at[last] = None;
        }
        for &(w, at) in &cfg.crashes {
            if w >= n_workers {
                continue;
            }
            plan.crash_at[w] = Some(match plan.crash_at[w] {
                Some(prev) => prev.min(at),
                None => at,
            });
        }
        plan
    }

    /// Any worker scheduled to crash?
    pub fn has_crashes(&self) -> bool {
        self.crash_at.iter().any(|c| c.is_some())
    }

    /// Any slowdown window scheduled?
    pub fn has_slowdowns(&self) -> bool {
        self.slowdowns.iter().any(|s| s.is_some())
    }

    /// Runtime multiplier for worker `w` at time `now`, if a slowdown
    /// window is active.
    #[inline]
    pub fn slowdown_factor(&self, w: WorkerId, now: Micros) -> Option<f64> {
        match self.slowdowns.get(w).copied().flatten() {
            Some(win) if win.start_us <= now && now < win.end_us => Some(win.factor),
            _ => None,
        }
    }
}

/// Message-level fault shim for the live fabric
/// (`coordinator::network::run_fabric_faults`). The fabric thread applies
/// it to each parcel as it is accepted, in arrival order, so the extra
/// latency stream is deterministic for a given seed.
#[derive(Debug, Clone)]
pub struct NetFaults {
    pub drop_prob: f64,
    pub delay_prob: f64,
    pub delay_us: Micros,
    /// Latency a "dropped" message pays for its retransmit.
    pub retransmit_us: Micros,
    rng: Rng,
}

impl NetFaults {
    /// Extra delivery latency for the next message: retransmit cost if it
    /// is dropped, `delay_us` if it is delayed, 0 otherwise. Exactly one
    /// draw per message keeps the stream stable.
    pub fn extra_delay_us(&mut self) -> Micros {
        let roll = self.rng.f64();
        if roll < self.drop_prob {
            self.retransmit_us
        } else if roll < self.drop_prob + self.delay_prob {
            self.delay_us
        } else {
            0
        }
    }
}

/// Apply the shared `--crash-rate`/`--crash`/... CLI flags onto a
/// [`FaultConfig`]. Used by `simulate`, `serve`, and `experiment chaos` so
/// the knobs spell identically everywhere.
pub fn apply_fault_args(cfg: &mut FaultConfig, args: &Args) -> anyhow::Result<()> {
    cfg.crash_rate = args.get_f64("crash-rate", cfg.crash_rate);
    if let Some(spec) = args.get("crash") {
        cfg.crashes = parse_crash_spec(spec)?;
    }
    cfg.crash_window_us = args.get_u64("crash-window-ms", cfg.crash_window_us / MS) * MS;
    cfg.slowdown_rate = args.get_f64("slowdown-rate", cfg.slowdown_rate);
    cfg.slowdown_factor = args.get_f64("slowdown-factor", cfg.slowdown_factor);
    cfg.drop_prob = args.get_f64("drop-prob", cfg.drop_prob);
    cfg.delay_prob = args.get_f64("delay-prob", cfg.delay_prob);
    cfg.fetch_fail_prob = args.get_f64("fetch-fail-prob", cfg.fetch_fail_prob);
    cfg.heartbeat_timeout_us =
        args.get_u64("heartbeat-timeout-ms", cfg.heartbeat_timeout_us / MS) * MS;
    cfg.seed = args.get_u64("fault-seed", cfg.seed);
    Ok(())
}

/// Parse a comma-separated `worker@ms` crash list, e.g. `0@1500,2@3000`.
pub fn parse_crash_spec(spec: &str) -> anyhow::Result<Vec<(WorkerId, Micros)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.is_empty()) {
        let (w, ms) = part
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("bad crash spec {part:?}: want WORKER@MS"))?;
        let w: WorkerId =
            w.trim().parse().map_err(|e| anyhow::anyhow!("bad worker in {part:?}: {e}"))?;
        let ms: u64 =
            ms.trim().parse().map_err(|e| anyhow::anyhow!("bad time in {part:?}: {e}"))?;
        out.push((w, ms * MS));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_inert() {
        let cfg = FaultConfig::default();
        assert!(!cfg.enabled());
        assert!(cfg.net_faults().is_none());
        let plan = FaultPlan::materialize(&cfg, 5);
        assert_eq!(plan, FaultPlan::none(5));
        assert!(!plan.has_crashes());
        assert!(!plan.has_slowdowns());
    }

    #[test]
    fn materialize_is_deterministic() {
        let cfg = FaultConfig { crash_rate: 0.5, slowdown_rate: 0.5, ..Default::default() };
        let a = FaultPlan::materialize(&cfg, 8);
        let b = FaultPlan::materialize(&cfg, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn sampled_crash_rate_one_spares_a_survivor() {
        let cfg = FaultConfig { crash_rate: 1.0, ..Default::default() };
        let plan = FaultPlan::materialize(&cfg, 6);
        let alive = plan.crash_at.iter().filter(|c| c.is_none()).count();
        assert_eq!(alive, 1, "safety valve spares exactly the latest crasher");
        for c in plan.crash_at.iter().flatten() {
            assert!(*c < cfg.crash_window_us);
        }
    }

    #[test]
    fn explicit_crashes_union_and_may_kill_all() {
        let cfg = FaultConfig {
            crashes: vec![(0, SEC), (1, 2 * SEC), (2, 3 * SEC), (9, SEC)],
            ..Default::default()
        };
        let plan = FaultPlan::materialize(&cfg, 3);
        assert_eq!(plan.crash_at, vec![Some(SEC), Some(2 * SEC), Some(3 * SEC)]);
        // Worker 9 is out of range and ignored; all in-range workers die.
        assert!(plan.has_crashes());
    }

    #[test]
    fn explicit_crash_takes_earlier_time() {
        // With crash_rate 1.0 every worker samples a time; an explicit
        // earlier time must win, an explicit later one must lose.
        let cfg = FaultConfig { crash_rate: 1.0, crashes: vec![(0, 0)], ..Default::default() };
        let plan = FaultPlan::materialize(&cfg, 4);
        assert_eq!(plan.crash_at[0], Some(0));
    }

    #[test]
    fn slowdown_window_bounds() {
        let cfg = FaultConfig { slowdown_rate: 1.0, ..Default::default() };
        let plan = FaultPlan::materialize(&cfg, 4);
        assert!(plan.has_slowdowns());
        for (w, win) in plan.slowdowns.iter().enumerate() {
            let win = win.expect("rate 1.0 slows every worker");
            assert_eq!(win.end_us - win.start_us, cfg.slowdown_us);
            assert_eq!(plan.slowdown_factor(w, win.start_us), Some(win.factor));
            assert_eq!(plan.slowdown_factor(w, win.end_us), None);
        }
    }

    #[test]
    fn crash_rate_independent_of_slowdown_rate() {
        // Fixed draw count per worker: toggling the slowdown rate must not
        // change who crashes or when.
        let a = FaultPlan::materialize(
            &FaultConfig { crash_rate: 0.5, ..Default::default() },
            8,
        );
        let b = FaultPlan::materialize(
            &FaultConfig { crash_rate: 0.5, slowdown_rate: 0.9, ..Default::default() },
            8,
        );
        assert_eq!(a.crash_at, b.crash_at);
    }

    #[test]
    fn retry_backoff_doubles_and_saturates() {
        let r = RetryConfig { max_attempts: 5, backoff_base_us: 100 };
        assert_eq!(r.backoff_us(0), 100);
        assert_eq!(r.backoff_us(1), 200);
        assert_eq!(r.backoff_us(4), 1600);
        // Huge attempt numbers clamp instead of overflowing.
        assert_eq!(r.backoff_us(200), 100 << 16);
        let big = RetryConfig { max_attempts: 3, backoff_base_us: Micros::MAX / 2 };
        assert_eq!(big.backoff_us(63), Micros::MAX);
    }

    #[test]
    fn net_faults_partition_the_unit_interval() {
        let cfg = FaultConfig { drop_prob: 0.5, delay_prob: 0.5, ..Default::default() };
        let mut nf = cfg.net_faults().expect("net faults configured");
        for _ in 0..256 {
            let d = nf.extra_delay_us();
            assert!(d == nf.retransmit_us || d == nf.delay_us, "d={d}");
        }
        let cfg = FaultConfig { delay_prob: 1.0, ..Default::default() };
        let mut nf = cfg.net_faults().expect("delay-only");
        assert_eq!(nf.extra_delay_us(), cfg.delay_us);
    }

    #[test]
    fn parse_crash_spec_roundtrip() {
        assert_eq!(parse_crash_spec("0@1500,2@3000").unwrap(), vec![(0, 1500 * MS), (2, 3 * SEC)]);
        assert_eq!(parse_crash_spec("").unwrap(), vec![]);
        assert!(parse_crash_spec("1").is_err());
        assert!(parse_crash_spec("x@5").is_err());
        assert!(parse_crash_spec("1@x").is_err());
    }
}
