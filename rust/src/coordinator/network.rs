//! Delay-modeling message fabric for the live coordinator.
//!
//! Stands in for Cascade's RDMA/DPDK data plane (§5.1): senders hand a
//! message plus a delivery delay to the fabric thread, which holds it in a
//! time-ordered heap and forwards it to the destination worker's channel
//! when the (scaled) transfer would have completed. Zero-delay messages are
//! forwarded immediately, preserving sender order.

use crate::fault::NetFaults;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::mpsc::{Receiver, Sender};
use std::time::{Duration, Instant};

/// A message destined for worker `to` after `delay`.
pub struct Parcel<M> {
    pub to: usize,
    pub delay: Duration,
    pub msg: M,
}

/// Fabric thread main loop: deliver parcels in deadline order.
pub fn run_fabric<M: Send + 'static>(rx: Receiver<Parcel<M>>, outs: Vec<Sender<M>>) {
    run_fabric_faults(rx, outs, None)
}

/// `run_fabric` with an optional fault-injection shim: each accepted
/// parcel may pay extra delivery latency (a modeled drop-and-retransmit
/// or a delay spike). Faults apply in arrival order — one RNG draw per
/// parcel — so the injected latency stream is deterministic for a given
/// seed. The shim's delays are wall-clock `Micros` (the caller pre-scales
/// profiled time; the fabric has no notion of `time_scale`).
pub fn run_fabric_faults<M: Send + 'static>(
    rx: Receiver<Parcel<M>>,
    outs: Vec<Sender<M>>,
    mut faults: Option<NetFaults>,
) {
    struct Pending<M> {
        at: Instant,
        seq: u64,
        to: usize,
        msg: M,
    }
    impl<M> PartialEq for Pending<M> {
        fn eq(&self, o: &Self) -> bool {
            self.at == o.at && self.seq == o.seq
        }
    }
    impl<M> Eq for Pending<M> {}
    impl<M> PartialOrd for Pending<M> {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl<M> Ord for Pending<M> {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            self.at.cmp(&o.at).then(self.seq.cmp(&o.seq))
        }
    }

    let mut heap: BinaryHeap<Reverse<Pending<M>>> = BinaryHeap::new();
    let mut seq = 0u64;
    loop {
        // Wait bounded by the next deadline.
        let timeout = heap
            .peek()
            .map(|Reverse(p)| p.at.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(parcel) => {
                seq += 1;
                let extra = match &mut faults {
                    Some(nf) => Duration::from_micros(nf.extra_delay_us()),
                    None => Duration::ZERO,
                };
                heap.push(Reverse(Pending {
                    at: Instant::now() + parcel.delay + extra,
                    seq,
                    to: parcel.to,
                    msg: parcel.msg,
                }));
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                // Drain what's left, then exit.
                while let Some(Reverse(p)) = heap.pop() {
                    let rem = p.at.saturating_duration_since(Instant::now());
                    if !rem.is_zero() {
                        std::thread::sleep(rem);
                    }
                    let _ = outs[p.to].send(p.msg);
                }
                return;
            }
        }
        // Deliver everything due.
        while let Some(Reverse(p)) = heap.peek() {
            if p.at > Instant::now() {
                break;
            }
            if let Some(Reverse(p)) = heap.pop() {
                let _ = outs[p.to].send(p.msg);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn delivers_in_deadline_order() {
        let (tx, rx) = channel::<Parcel<u32>>();
        let (out_tx, out_rx) = channel::<u32>();
        let h = std::thread::spawn(move || run_fabric(rx, vec![out_tx]));
        tx.send(Parcel { to: 0, delay: Duration::from_millis(40), msg: 2 }).unwrap();
        tx.send(Parcel { to: 0, delay: Duration::from_millis(5), msg: 1 }).unwrap();
        drop(tx);
        let a = out_rx.recv().unwrap();
        let b = out_rx.recv().unwrap();
        h.join().unwrap();
        assert_eq!((a, b), (1, 2));
    }

    #[test]
    fn zero_delay_preserves_order() {
        let (tx, rx) = channel::<Parcel<u32>>();
        let (out_tx, out_rx) = channel::<u32>();
        let h = std::thread::spawn(move || run_fabric(rx, vec![out_tx]));
        for i in 0..20 {
            tx.send(Parcel { to: 0, delay: Duration::ZERO, msg: i }).unwrap();
        }
        drop(tx);
        let got: Vec<u32> = (0..20).map(|_| out_rx.recv().unwrap()).collect();
        h.join().unwrap();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn fault_shim_adds_latency_but_loses_nothing() {
        use crate::fault::FaultConfig;
        // delay_prob 1.0: every parcel pays delay_us extra, none are lost.
        let cfg = FaultConfig {
            delay_prob: 1.0,
            delay_us: 5_000, // 5ms wall
            ..Default::default()
        };
        let nf = cfg.net_faults().expect("net faults configured");
        let (tx, rx) = channel::<Parcel<u32>>();
        let (out_tx, out_rx) = channel::<u32>();
        let h = std::thread::spawn(move || run_fabric_faults(rx, vec![out_tx], Some(nf)));
        let t0 = Instant::now();
        for i in 0..8 {
            tx.send(Parcel { to: 0, delay: Duration::ZERO, msg: i }).unwrap();
        }
        drop(tx);
        let got: Vec<u32> = (0..8).map(|_| out_rx.recv().unwrap()).collect();
        h.join().unwrap();
        assert_eq!(got, (0..8).collect::<Vec<_>>(), "a dropped message still retransmits");
        assert!(t0.elapsed() >= Duration::from_millis(5), "delay faults add latency");
    }

    #[test]
    fn routes_to_correct_worker() {
        let (tx, rx) = channel::<Parcel<&'static str>>();
        let (t0, r0) = channel();
        let (t1, r1) = channel();
        let h = std::thread::spawn(move || run_fabric(rx, vec![t0, t1]));
        tx.send(Parcel { to: 1, delay: Duration::ZERO, msg: "one" }).unwrap();
        tx.send(Parcel { to: 0, delay: Duration::ZERO, msg: "zero" }).unwrap();
        drop(tx);
        assert_eq!(r1.recv().unwrap(), "one");
        assert_eq!(r0.recv().unwrap(), "zero");
        h.join().unwrap();
    }
}
