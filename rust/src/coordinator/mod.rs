//! Live multi-worker coordinator: the deployed form of the system.
//!
//! Worker *threads* (one per worker node) own their execution queue and GPU
//! cache, exchange ADFG dispatch messages and intermediate outputs through
//! a delay-modeling network thread, publish SST rows at the configured push
//! rate, and execute each ML vertex **for real** through the PJRT runtime
//! (the AOT-compiled tiny transformer for that vertex's model). Python is
//! never on this path.
//!
//! Profiled durations (model fetch over PCIe, network transfers, the resid-
//! ual of each task's profiled runtime beyond the real PJRT compute) are
//! scaled down by `time_scale` so a minutes-long workload replays in
//! seconds while preserving every ratio the scheduler reasons about —
//! the same rescaling trick the paper applies to the Alibaba trace. With
//! `time_scale = 1` the coordinator runs at profiled speed.
//!
//! `exp::validate` replays one workload through this coordinator and the
//! simulator and checks the medians agree — the paper's §5.4 validation.

mod cluster;
mod network;

pub use cluster::{LiveCluster, LiveConfig, LiveReport};

use crate::util::args::Args;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Logged once when a poisoned lock is first recovered, so a crashed
/// worker thread shows up in stderr without spamming every subsequent
/// lock acquisition.
static POISON_SEEN: AtomicBool = AtomicBool::new(false);

/// Acquire `m`, recovering from lock poisoning instead of panicking
/// (lint rule L3: the live path must degrade, not die). A mutex is
/// poisoned only when a thread panicked while holding it; the protected
/// state (SST rows, job tables, tracer ring) stays structurally valid for
/// every operation the coordinator performs, so continuing with the
/// recovered guard is safe — the run's *numbers* may be off, which the
/// one-shot stderr note makes visible.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            if !POISON_SEEN.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "coordinator: lock poisoned by a crashed worker thread; continuing with recovered state"
                );
            }
            poisoned.into_inner()
        }
    }
}

/// `compass serve` CLI: run the live coordinator on a Poisson workload.
pub fn cli_serve(args: &Args) -> anyhow::Result<()> {
    use crate::config::{ClusterConfig, SchedulerKind};
    let scheduler = SchedulerKind::parse(args.get_or("scheduler", "compass"))
        .ok_or_else(|| anyhow::anyhow!("unknown scheduler"))?;
    let trace_out = args.get_path("trace-out");
    let metrics_out = args.get_path("metrics-out");
    let mut cfg = ClusterConfig::default()
        .with_scheduler(scheduler)
        .with_workers(args.get_usize("workers", 5))
        .with_seed(args.get_u64("seed", 42));
    // Either output needs the tracer running.
    cfg.trace.enabled |= trace_out.is_some() || metrics_out.is_some();
    cfg.cost.batch.batch_max = args.get_usize("batch-max", 1).max(1);
    cfg.cost.batch.window_us = args.get_u64("batch-window-us", cfg.cost.batch.window_us);
    if let Some(a) = args.get("batch-alpha") {
        cfg.cost.batch.alpha_override = Some(a.parse()?);
    }
    crate::fault::apply_fault_args(&mut cfg.fault, args)?;
    let rate = args.get_f64("rate", 2.0);
    let n_jobs = args.get_usize("jobs", 40);
    let seed = cfg.seed ^ 0x9e37;
    let jobs = crate::workload::poisson(rate, n_jobs, &[], seed);

    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(crate::runtime::artifacts_dir);
    let metas = crate::runtime::Runtime::read_manifest(&artifacts)?;
    println!("{} model artifacts in {}", metas.len(), artifacts.display());

    let live = LiveConfig { time_scale: args.get_f64("time-scale", 100.0), ..Default::default() };
    let report = LiveCluster::run(cfg, live, Some(artifacts), jobs)?;
    let m = &report.metrics;
    println!(
        "served {} jobs | mean latency {:.2} s (profiled time) | mean slowdown {:.2} | p95 slowdown {:.2}",
        m.jobs.len(),
        m.mean_latency_s(),
        m.mean_slowdown(),
        crate::util::stats::percentile(&m.slowdowns(), 95.0),
    );
    println!(
        "throughput {:.1} jobs/s (profiled) | hit rate {:.1}% | {} PJRT executions, {} µs mean exec",
        m.jobs.len() as f64 / (m.span_us as f64 / 1e6),
        m.cache_hit_rate(),
        report.pjrt_executions,
        report.mean_pjrt_exec_us,
    );
    if m.faults != crate::metrics::FaultStats::default() {
        println!(
            "faults: {} workers failed | {} tasks re-placed | {} retries | {} jobs failed | completion {:.1}%",
            m.faults.workers_failed,
            m.faults.tasks_re_placed,
            m.faults.task_retries,
            m.faults.jobs_failed,
            m.completion_rate()
        );
    }
    crate::obs::write_outputs(
        &report.trace,
        &report.metrics,
        trace_out.as_deref(),
        metrics_out.as_deref(),
    )?;
    if let Some(p) = &trace_out {
        println!(
            "chrome trace ({} events) written to {}",
            report.trace.events.len(),
            p.display()
        );
    }
    if let Some(p) = &metrics_out {
        println!("metrics snapshot written to {}", p.display());
    }
    Ok(())
}
