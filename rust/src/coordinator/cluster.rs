//! The live cluster: worker threads + message fabric + shared SST + PJRT.
//!
//! Event-for-event this mirrors the simulator (`sim::Simulator`): the same
//! dispatcher rules, fetch/execute overlap, join early-send, and SST push
//! rate-limiting — but driven by wall-clock time (scaled) and real message
//! passing between threads, with each ML vertex running its AOT-compiled
//! model through PJRT. This is the system `exp::validate` compares against
//! the simulator, reproducing the paper's §5.4 validation.

use super::lock_recover;
use super::network::{run_fabric_faults, Parcel};
use crate::config::ClusterConfig;
use crate::core::{hash_pair, Micros, ModelId, TaskId, WorkerId};
use crate::dfg::models::{model, model_bytes};
use crate::dfg::{pipelines, Adfg, Dfg, Job};
use crate::fault::FaultPlan;
use crate::gpu::CacheEventKind;
use crate::metrics::{FaultStats, JobOutcome, JobRecord, MetricsSink, WorkerMetrics};
use crate::obs::{SchedPhase, Trace, TraceEvent, Tracer};
use crate::runtime::Runtime;
use crate::sched::{self, AssignCtx, ClusterView, DecisionProbe, PlanCell, Scheduler};
use crate::sim::QTask;
use crate::sst::{Sst, SstRow};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Live-mode specific knobs.
#[derive(Debug, Clone, Copy)]
pub struct LiveConfig {
    /// Profiled-time / wall-time ratio. 100 ⇒ a 300 s workload replays in
    /// 3 s while preserving all cost ratios. 1 ⇒ real time.
    pub time_scale: f64,
    /// Hard wall-clock cap for one run.
    pub wall_timeout: Duration,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig { time_scale: 100.0, wall_timeout: Duration::from_secs(120) }
    }
}

/// Messages delivered to worker threads via the fabric.
enum Msg {
    /// Client request arriving at the ingress worker.
    Job { job_idx: usize },
    /// ADFG dispatch: task joins this worker's execution queue.
    Enqueue { job_idx: usize, task: TaskId },
    /// One input object for (job, task) landed here.
    Input { job_idx: usize, task: TaskId },
    /// Self-scheduled PCIe fetch completion.
    FetchDone { model: ModelId },
    /// Self-scheduled execution completion.
    ExecDone { job_idx: usize, task: TaskId },
    /// Self-scheduled batching-window expiry: start the held batch even if
    /// it never filled. Stale once `hold_until` moved past `deadline`.
    BatchWindow { deadline: Micros },
    /// Self-scheduled completion of a coalesced batch.
    BatchDone,
    Stop,
}

/// Mutable per-job state shared across workers (stands in for the ADFG
/// piggybacking + Cascade object metadata of the real system).
struct LiveJob {
    job: Job,
    adfg: Adfg,
    inputs_arrived: Vec<usize>,
    remaining_preds: Vec<usize>,
    output_worker: Vec<Option<WorkerId>>,
    sent: Vec<Vec<bool>>,
    /// True once any task of this job was re-placed after a worker
    /// failure; the job then completes as [`JobOutcome::Degraded`].
    disrupted: bool,
}

struct Shared {
    cfg: ClusterConfig,
    live: LiveConfig,
    dfgs: Vec<Dfg>,
    scheduler: Box<dyn Scheduler>,
    /// Artifacts directory; each worker thread loads its *own* PJRT client
    /// and executables from it (the xla handles are not Send — and a real
    /// worker owns its own GPU anyway).
    artifacts: Option<std::path::PathBuf>,
    sst: Mutex<Sst>,
    jobs: Mutex<Vec<LiveJob>>,
    speed: Vec<f64>,
    /// Profiled-time zero. Set *after* every worker finished loading its
    /// PJRT runtime (startup must not count as queueing delay).
    epoch: Mutex<Instant>,
    net_tx: Sender<Parcel<Msg>>,
    done_tx: Sender<JobRecord>,
    pjrt_execs: AtomicU64,
    pjrt_exec_ns: AtomicU64,
    /// Shared event tracer. Lock order: this is always the *innermost*
    /// lock — it is taken while holding `jobs` or `sst`, never the other
    /// way around.
    tracer: Mutex<Tracer>,
    /// Materialized fault schedule; `FaultPlan::none` when injection is
    /// off, in which case every fault code path below is inert.
    fault_plan: FaultPlan,
    faults_workers_failed: AtomicU64,
    faults_tasks_re_placed: AtomicU64,
    faults_task_retries: AtomicU64,
}

impl Shared {
    /// Profiled-time "now" in µs.
    fn now(&self) -> Micros {
        let epoch = *lock_recover(&self.epoch);
        (epoch.elapsed().as_micros() as f64 * self.live.time_scale) as Micros
    }

    /// Convert a profiled duration to wall-clock.
    fn to_wall(&self, profiled_us: Micros) -> Duration {
        Duration::from_micros((profiled_us as f64 / self.live.time_scale) as u64)
    }

    fn send(&self, to: WorkerId, delay_profiled_us: Micros, msg: Msg) {
        let _ = self
            .net_tx
            .send(Parcel { to, delay: self.to_wall(delay_profiled_us), msg });
    }

    /// Record a trace event: one branch and no lock when tracing is off.
    fn trace(&self, ev: TraceEvent) {
        if self.cfg.trace.enabled {
            lock_recover(&self.tracer).record(ev);
        }
    }
}

/// One worker node's thread-local state and main loop.
struct WorkerNode {
    id: WorkerId,
    shared: Arc<Shared>,
    /// This worker's own PJRT client + compiled models (loaded in-thread).
    runtime: Option<Runtime>,
    queue: Vec<QTask>,
    gpu: crate::gpu::GpuCache,
    /// Currently executing task(s): one entry normally, several when a
    /// same-model batch was coalesced (mirrors `sim::SimWorker::running`).
    running: Vec<QTask>,
    /// Profiled-time end of the running task (for FT estimates).
    exec_end: Micros,
    /// Batching-window deadline while this worker holds a partial batch.
    hold_until: Option<Micros>,
    fetching: Option<ModelId>,
    busy_us: Micros,
    executed: u64,
    rng: Rng,
    rx: Receiver<Msg>,
    /// Thread-local reusable planning scratch (each worker thread makes its
    /// own scheduling decisions, so no sharing — mirrors the simulator's).
    scratch: PlanCell,
    /// Fault injection: profiled instant this worker dies, if scheduled.
    crash_at: Option<Micros>,
    /// Set once `crash_at` passes; the worker then discards every message
    /// except `Stop` and stops pushing SST rows (silent failure).
    dead: bool,
    /// RNG for this worker's online fault draws (fetch failures).
    fault_rng: Rng,
    /// Consecutive fetch-failure counts per model (transient-fault retry).
    fetch_attempts: [u32; crate::dfg::models::N_MODELS],
}

impl WorkerNode {
    fn live_row(&self, now: Micros) -> SstRow {
        let batch = &self.shared.cfg.cost.batch;
        let remaining: Micros = if batch.enabled() {
            // Batching-aware drain: same-model queue entries coalesce, so
            // the queue clears faster than the serial sum (mirrors
            // `sim::SimWorker::ft_estimate`).
            use crate::dfg::models::{batch_alpha, N_MODELS};
            let mut count = [0u32; N_MODELS];
            let mut sum = [0u64; N_MODELS];
            let mut unmodeled = 0u64;
            for q in &self.queue {
                match q.model {
                    Some(m) => {
                        count[m as usize] += 1;
                        sum[m as usize] += q.runtime_us;
                    }
                    None => unmodeled += q.runtime_us,
                }
            }
            let mut drain = unmodeled;
            for m in 0..N_MODELS {
                if count[m] > 0 {
                    let alpha = batch.alpha(batch_alpha(m as ModelId));
                    drain += batch.drain_estimate_us(count[m] as usize, sum[m], alpha);
                }
            }
            drain
        } else {
            self.queue.iter().map(|q| q.runtime_us).sum()
        };
        let base = if !self.running.is_empty() { self.exec_end.max(now) } else { now };
        SstRow {
            ft_us: base + remaining,
            cache_bitmap: self.gpu.bitmap(),
            free_cache_bytes: self.gpu.free_bytes(),
            load_pushed_at: now,
            cache_pushed_at: now,
        }
    }

    fn push_sst(&self, now: Micros) {
        let row = self.live_row(now);
        let mut sst = lock_recover(&self.shared.sst);
        sst.push_load(self.id, row.ft_us, now);
        sst.push_cache(self.id, row.cache_bitmap, row.free_cache_bytes, now);
    }

    /// Copy published rows, refreshing our own row live.
    fn view_rows(&self, now: Micros) -> Vec<SstRow> {
        let mut rows = lock_recover(&self.shared.sst).rows().to_vec();
        rows[self.id] = self.live_row(now);
        rows
    }

    /// Run `assign` for a dispatchable task and ship ADFG + inputs.
    fn assign_and_dispatch(&self, job_idx: usize, task: TaskId) {
        let sh = &self.shared;
        let now = sh.now();
        let rows = self.view_rows(now);
        let mut probe =
            if sh.cfg.trace.enabled { DecisionProbe::on() } else { DecisionProbe::off() };
        let mut jobs = lock_recover(&sh.jobs);
        let planned_before = jobs[job_idx].adfg.get(task);
        let (target, pred_outputs) = {
            let js = &jobs[job_idx];
            let dfg = &sh.dfgs[js.job.kind.index()];
            let pred_outputs: Vec<(WorkerId, u64)> = if dfg.preds[task].is_empty() {
                vec![(self.id, js.job.input_bytes)]
            } else {
                dfg.preds[task]
                    .iter()
                    .map(|&p| {
                        (js.output_worker[p].expect("pred done"), dfg.vertices[p].output_bytes)
                    })
                    .collect()
            };
            let view = ClusterView {
                now,
                self_worker: self.id,
                rows: &rows,
                cost: &sh.cfg.cost,
                speed: &sh.speed,
                scratch: &self.scratch,
            };
            let ctx = AssignCtx {
                job: &js.job,
                dfg,
                task,
                planned: planned_before,
                pred_outputs: &pred_outputs,
            };
            (sh.scheduler.assign_probed(&ctx, &view, &mut probe), pred_outputs)
        };
        if probe.is_active() {
            sh.trace(TraceEvent::Decision {
                job: jobs[job_idx].job.id,
                task: task as u16,
                phase: SchedPhase::Adjust,
                decider: self.id as u16,
                chosen: target as u16,
                candidates: probe.take_single(),
                t: now,
            });
        }
        // Placement pointing at a poisoned row ⇒ this assign IS a recovery
        // re-placement (orphan drain and pinned-join rescue both land
        // here). Mirrors `sim::Simulator::assign_and_dispatch`.
        if planned_before.map_or(false, |p| rows[p].poisoned()) {
            jobs[job_idx].disrupted = true;
            sh.faults_tasks_re_placed.fetch_add(1, Ordering::Relaxed);
            sh.trace(TraceEvent::TaskRePlaced {
                job: jobs[job_idx].job.id,
                task: task as u16,
                from: planned_before.unwrap_or(self.id) as u16,
                to: target as u16,
                t: now,
            });
        }
        jobs[job_idx].adfg.set(task, target);

        let delta = if target == self.id { 0 } else { sh.cfg.cost.delta_net_us };
        sh.send(target, delta, Msg::Enqueue { job_idx, task });

        let dfg_idx = jobs[job_idx].job.kind.index();
        let preds = sh.dfgs[dfg_idx].preds[task].clone();
        if preds.is_empty() {
            let td = sh.cfg.cost.td_input(pred_outputs[0].1, self.id, target);
            sh.send(target, td, Msg::Input { job_idx, task });
        } else {
            for &p in &preds {
                let slot = sh.dfgs[dfg_idx].succs[p].iter().position(|&s| s == task).unwrap();
                if jobs[job_idx].sent[p][slot] {
                    continue;
                }
                jobs[job_idx].sent[p][slot] = true;
                let src = jobs[job_idx].output_worker[p].unwrap();
                let bytes = sh.dfgs[dfg_idx].vertices[p].output_bytes;
                let td = sh.cfg.cost.td_input(bytes, src, target);
                sh.send(target, td, Msg::Input { job_idx, task });
            }
        }
    }

    /// Run the real PJRT forward pass for this vertex's model.
    fn pjrt_execute(&self, m: ModelId) {
        if let Some(rt) = &self.runtime {
            if let Some(cm) = rt.get(model(m).artifact) {
                let t0 = Instant::now();
                let x = cm.smoke_input();
                if let Ok(y) = cm.execute(&x) {
                    std::hint::black_box(y.len());
                }
                self.shared.pjrt_execs.fetch_add(1, Ordering::Relaxed);
                self.shared
                    .pjrt_exec_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        }
    }

    /// Run one coalesced forward pass for a `b`-member batch: a single
    /// stacked PJRT call when the artifact is batch-capable, a per-member
    /// fallback loop otherwise (see `CompiledModel::execute_batch`).
    fn pjrt_execute_batch(&self, m: ModelId, b: usize) {
        if let Some(rt) = &self.runtime {
            if let Some(cm) = rt.get(model(m).artifact) {
                let t0 = Instant::now();
                let inputs = vec![cm.smoke_input(); b];
                if let Ok(ys) = cm.execute_batch(&inputs) {
                    std::hint::black_box(ys.len());
                }
                self.shared.pjrt_execs.fetch_add(1, Ordering::Relaxed);
                self.shared
                    .pjrt_exec_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        }
    }

    /// The dispatcher scan — mirrors `sim::Simulator::try_dispatch`.
    fn try_dispatch(&mut self) {
        self.dispatch(false);
    }

    /// `force_start` (batching-window expiry) starts a held partial batch
    /// instead of re-arming the hold.
    fn dispatch(&mut self, force_start: bool) {
        let sh = self.shared.clone();
        let now = sh.now();
        let jobs = lock_recover(&sh.jobs);

        // Fetch scan (PCIe serial; overlaps execution).
        if self.fetching.is_none() {
            // Deduped in first-appearance order: the eviction planner only
            // needs each upcoming model once.
            let mut seen = 0u64;
            let lookahead: Vec<ModelId> = self
                .queue
                .iter()
                .filter_map(|q| q.model)
                .filter(|&m| {
                    let bit = 1u64 << m;
                    let fresh = seen & bit == 0;
                    seen |= bit;
                    fresh
                })
                .collect();
            let mut fetch: Option<(usize, ModelId)> = None;
            for (i, qt) in self.queue.iter().enumerate() {
                let js = &jobs[qt.job_idx];
                let dfg = &sh.dfgs[js.job.kind.index()];
                let needed = dfg.preds[qt.task].len().max(1);
                if js.inputs_arrived[qt.task] < needed {
                    continue;
                }
                if let Some(m) = qt.model {
                    if !self.gpu.contains(m) {
                        if self.gpu.plan_eviction(model_bytes(m), &lookahead).is_some() {
                            fetch = Some((i, m));
                        }
                        break;
                    }
                }
            }
            if let Some((i, m)) = fetch {
                let victims = self
                    .gpu
                    .plan_eviction(model_bytes(m), &lookahead)
                    .expect("eviction plan vanished");
                for v in victims {
                    self.gpu.evict(v, now);
                }
                self.gpu.record_miss(m, now);
                self.queue[i].caused_fetch = true;
                self.fetching = Some(m);
                sh.trace(TraceEvent::FetchStart { worker: self.id as u16, model: m, t: now });
                let td = sh.cfg.cost.td_model(model_bytes(m));
                sh.send(self.id, td, Msg::FetchDone { model: m });
            }
        }

        // Start scan (GPU executes one task — or one coalesced batch — at
        // a time).
        if self.running.is_empty() {
            let batch = sh.cfg.cost.batch;
            let mut start: Option<usize> = None;
            for (i, qt) in self.queue.iter().enumerate() {
                let js = &jobs[qt.job_idx];
                let dfg = &sh.dfgs[js.job.kind.index()];
                let needed = dfg.preds[qt.task].len().max(1);
                if js.inputs_arrived[qt.task] < needed {
                    continue;
                }
                match qt.model {
                    Some(m) if !self.gpu.contains(m) => continue,
                    _ => {
                        start = Some(i);
                        break;
                    }
                }
            }
            if let (Some(i), Some(m), true) =
                (start, start.and_then(|i| self.queue[i].model), batch.enabled())
            {
                // Coalesce consecutive same-model ready queue-mates behind
                // the leader, up to batch_max.
                let mut members = vec![i];
                for (j, qt) in self.queue.iter().enumerate().skip(i + 1) {
                    if members.len() >= batch.batch_max {
                        break;
                    }
                    if qt.model != Some(m) {
                        break;
                    }
                    let js = &jobs[qt.job_idx];
                    let dfg = &sh.dfgs[js.job.kind.index()];
                    if js.inputs_arrived[qt.task] < dfg.preds[qt.task].len().max(1) {
                        break;
                    }
                    members.push(j);
                }
                let full = members.len() >= batch.batch_max;
                if !full && batch.window_us > 0 && !force_start {
                    // Hold the GPU briefly for queue-mates to show up; the
                    // window self-message fires a forced dispatch.
                    if self.hold_until.is_none() {
                        let deadline = now + batch.window_us;
                        self.hold_until = Some(deadline);
                        sh.send(self.id, batch.window_us, Msg::BatchWindow { deadline });
                    }
                    return;
                }
                drop(jobs);
                self.start_batch(&members, m);
                return;
            }
            drop(jobs);
            if let Some(i) = start {
                let qt = self.queue.remove(i);
                if let Some(m) = qt.model {
                    if !qt.caused_fetch {
                        self.gpu.record_hit(m, now);
                    }
                    self.gpu.pin(m);
                    // Real compute, inside the task's profiled window.
                    self.pjrt_execute(m);
                }
                self.busy_us += qt.runtime_us;
                self.executed += 1;
                self.hold_until = None;
                let delay = qt.runtime_us;
                let (job_idx, task) = (qt.job_idx, qt.task);
                let exec_start = sh.now();
                self.exec_end = exec_start + delay;
                self.running.push(qt);
                if sh.cfg.trace.enabled {
                    let job = lock_recover(&sh.jobs)[job_idx].job.id;
                    sh.trace(TraceEvent::ExecStart {
                        job,
                        task: task as u16,
                        worker: self.id as u16,
                        t: exec_start,
                    });
                }
                sh.send(self.id, delay, Msg::ExecDone { job_idx, task });
            }
        }
    }

    /// Pull `members` (ascending queue indices) out of the queue and run
    /// them as one coalesced batch of model `m`.
    fn start_batch(&mut self, members: &[usize], m: ModelId) {
        let sh = self.shared.clone();
        let batch = sh.cfg.cost.batch;
        for &j in members.iter().rev() {
            let qt = self.queue.remove(j);
            self.running.push(qt);
        }
        self.running.reverse();
        let now = sh.now();
        let (mut max_us, mut sum_us) = (0u64, 0u64);
        for qt in &self.running {
            max_us = max_us.max(qt.runtime_us);
            sum_us += qt.runtime_us;
            if !qt.caused_fetch {
                self.gpu.record_hit(m, now);
            }
            self.gpu.pin(m);
        }
        // One real forward pass covers the whole batch.
        self.pjrt_execute_batch(m, self.running.len());
        let alpha = batch.alpha(crate::dfg::models::batch_alpha(m));
        let delay = batch.batch_runtime_us(max_us, sum_us, alpha);
        self.busy_us += delay;
        self.executed += self.running.len() as u64;
        self.hold_until = None;
        let exec_start = sh.now();
        self.exec_end = exec_start + delay;
        sh.trace(TraceEvent::BatchFormed {
            worker: self.id as u16,
            model: m,
            size: self.running.len() as u16,
            t: exec_start,
        });
        if sh.cfg.trace.enabled {
            let jobs = lock_recover(&sh.jobs);
            for qt in &self.running {
                sh.trace(TraceEvent::ExecStart {
                    job: jobs[qt.job_idx].job.id,
                    task: qt.task as u16,
                    worker: self.id as u16,
                    t: exec_start,
                });
            }
        }
        sh.send(self.id, delay, Msg::BatchDone);
    }

    fn handle_exec_done(&mut self, job_idx: usize, task: TaskId) {
        let qt = self.running.pop().expect("exec done without running");
        debug_assert!(self.running.is_empty(), "solo exec done with batch-mates running");
        debug_assert_eq!((qt.job_idx, qt.task), (job_idx, task));
        if let Some(m) = qt.model {
            self.gpu.unpin(m);
        }
        let now = self.shared.now();
        self.retire_task(job_idx, task, now);
        self.try_dispatch();
    }

    /// A coalesced batch finished: every member completes at the same
    /// instant (mirrors `sim::Simulator::handle_batch_done`).
    fn handle_batch_done(&mut self) {
        let sh = self.shared.clone();
        let now = sh.now();
        let model = self.running.first().and_then(|q| q.model).expect("batch without model");
        sh.trace(TraceEvent::BatchExecuted {
            worker: self.id as u16,
            model,
            size: self.running.len() as u16,
            t: now,
        });
        let done = std::mem::take(&mut self.running);
        for _ in &done {
            self.gpu.unpin(model);
        }
        for qt in done {
            self.retire_task(qt.job_idx, qt.task, now);
        }
        self.try_dispatch();
    }

    /// Post-execution bookkeeping for one finished task: trace, output
    /// registration, job completion, and the successor walk.
    fn retire_task(&mut self, job_idx: usize, task: TaskId, now: Micros) {
        let sh = self.shared.clone();
        let (exit, succs, dfg_idx, job_id) = {
            let mut jobs = lock_recover(&sh.jobs);
            if jobs[job_idx].output_worker[task].is_some() {
                // Already retired: a failure-recovery re-placement ran a
                // second copy of this task (split-brain on a detection
                // false positive). First finisher wins; duplicates are
                // absorbed here so the successor walk runs exactly once.
                return;
            }
            jobs[job_idx].output_worker[task] = Some(self.id);
            let js = &jobs[job_idx];
            let dfg_idx = js.job.kind.index();
            let d = &sh.dfgs[dfg_idx];
            (d.exit, d.succs[task].clone(), dfg_idx, js.job.id)
        };
        sh.trace(TraceEvent::ExecEnd {
            job: job_id,
            task: task as u16,
            worker: self.id as u16,
            t: now,
        });

        if task == exit {
            let jobs = lock_recover(&sh.jobs);
            let js = &jobs[job_idx];
            let outcome = if js.disrupted {
                JobOutcome::Degraded
            } else {
                JobOutcome::Completed
            };
            sh.trace(TraceEvent::JobComplete {
                job: js.job.id,
                kind: js.job.kind,
                latency_us: now.saturating_sub(js.job.arrival_us),
                t: now,
            });
            if outcome == JobOutcome::Degraded {
                sh.trace(TraceEvent::JobDegraded { job: js.job.id, kind: js.job.kind, t: now });
            }
            let _ = sh.done_tx.send(JobRecord {
                kind: js.job.kind,
                arrival_us: js.job.arrival_us,
                completion_us: now,
                lower_bound_us: sh.dfgs[dfg_idx].lower_bound_us,
                outcome,
            });
        }

        for (slot, &s) in succs.iter().enumerate() {
            let ready = {
                let mut jobs = lock_recover(&sh.jobs);
                jobs[job_idx].remaining_preds[s] -= 1;
                jobs[job_idx].remaining_preds[s] == 0
            };
            if ready {
                self.assign_and_dispatch(job_idx, s);
            } else {
                // Join early-send when the placement is pre-coordinated.
                let mut jobs = lock_recover(&sh.jobs);
                let dfg = &sh.dfgs[dfg_idx];
                if dfg.is_join(s) {
                    if let Some(target) = jobs[job_idx].adfg.get(s) {
                        if !jobs[job_idx].sent[task][slot] {
                            jobs[job_idx].sent[task][slot] = true;
                            let bytes = dfg.vertices[task].output_bytes;
                            let td = sh.cfg.cost.td_input(bytes, self.id, target);
                            sh.send(target, td, Msg::Input { job_idx, task: s });
                        }
                    }
                }
            }
        }
    }

    fn handle_job(&mut self, job_idx: usize) {
        let sh = self.shared.clone();
        let now = sh.now();
        let rows = self.view_rows(now);
        let traced = sh.cfg.trace.enabled;
        if traced {
            let (id, kind) = {
                let jobs = lock_recover(&sh.jobs);
                (jobs[job_idx].job.id, jobs[job_idx].job.kind)
            };
            sh.trace(TraceEvent::JobArrive { job: id, kind, t: now });
            // Sample how stale the SST view feeding this plan was (§5.2).
            let sst = lock_recover(&sh.sst);
            for w in 0..sh.cfg.n_workers {
                let (load, cache) = sst.staleness_of(w, now);
                sh.trace(TraceEvent::SstStaleness {
                    worker: w as u16,
                    load_staleness_us: load,
                    cache_staleness_us: cache,
                    t: now,
                });
            }
        }
        let mut probe = if traced { DecisionProbe::on() } else { DecisionProbe::off() };
        let (entry, adfg) = {
            let jobs = lock_recover(&sh.jobs);
            let js = &jobs[job_idx];
            let dfg = &sh.dfgs[js.job.kind.index()];
            let view = ClusterView {
                now,
                self_worker: self.id,
                rows: &rows,
                cost: &sh.cfg.cost,
                speed: &sh.speed,
                scratch: &self.scratch,
            };
            (dfg.entry, sh.scheduler.plan_probed(&js.job, dfg, &view, &mut probe))
        };
        if probe.is_active() {
            let job = lock_recover(&sh.jobs)[job_idx].job.id;
            for (task, candidates) in probe.take_records() {
                let chosen = adfg.get(task).unwrap_or(self.id);
                sh.trace(TraceEvent::Decision {
                    job,
                    task: task as u16,
                    phase: SchedPhase::Plan,
                    decider: self.id as u16,
                    chosen: chosen as u16,
                    candidates,
                    t: now,
                });
            }
        }
        lock_recover(&sh.jobs)[job_idx].adfg = adfg;
        self.assign_and_dispatch(job_idx, entry);
    }

    fn handle_enqueue(&mut self, job_idx: usize, task: TaskId) {
        let sh = self.shared.clone();
        let (base, model) = {
            let jobs = lock_recover(&sh.jobs);
            let dfg = &sh.dfgs[jobs[job_idx].job.kind.index()];
            (
                (dfg.vertices[task].mean_runtime_us as f64 * sh.speed[self.id]).max(1.0),
                dfg.vertices[task].model,
            )
        };
        let mut runtime = self.rng.jitter(base, sh.cfg.runtime_jitter, 100.0) as Micros;
        // Transient slowdown fault: a degraded-but-alive worker. Pure
        // window lookup, no RNG draw — inert when the plan has none.
        if let Some(f) = sh.fault_plan.slowdown_factor(self.id, sh.now()) {
            runtime = (runtime as f64 * f) as Micros;
        }
        self.queue.push(QTask { job_idx, task, model, runtime_us: runtime, caused_fetch: false });
        if sh.cfg.trace.enabled {
            let job = lock_recover(&sh.jobs)[job_idx].job.id;
            sh.trace(TraceEvent::TaskEnqueue {
                job,
                task: task as u16,
                worker: self.id as u16,
                t: sh.now(),
            });
        }
        self.try_dispatch();
    }

    /// A model fetch completed — or, under fault injection, maybe failed
    /// in transit. Transient fetch failures retry with exponential
    /// backoff; the final attempt always lands, so a fetch never wedges a
    /// worker permanently. `fetching` stays `Some` across retries: the
    /// PCIe link is busy re-transferring.
    fn handle_fetch_done(&mut self, model: ModelId) {
        debug_assert_eq!(self.fetching, Some(model));
        let sh = self.shared.clone();
        let now = sh.now();
        let prob = sh.cfg.fault.fetch_fail_prob;
        if prob > 0.0 {
            let retry = sh.cfg.fault.retry;
            let attempt = self.fetch_attempts[model as usize];
            let last = attempt + 1 >= retry.max_attempts.max(1);
            if !last && self.fault_rng.f64() < prob {
                self.fetch_attempts[model as usize] = attempt + 1;
                sh.faults_task_retries.fetch_add(1, Ordering::Relaxed);
                sh.trace(TraceEvent::TaskRetried {
                    worker: self.id as u16,
                    model,
                    attempt: attempt as u16,
                    t: now,
                });
                let td = sh.cfg.cost.td_model(model_bytes(model));
                sh.send(
                    self.id,
                    retry.backoff_us(attempt).saturating_add(td),
                    Msg::FetchDone { model },
                );
                return;
            }
            self.fetch_attempts[model as usize] = 0;
        }
        self.fetching = None;
        self.gpu.insert(model, now);
        sh.trace(TraceEvent::FetchEnd { worker: self.id as u16, model, t: now });
        self.try_dispatch();
    }

    /// Load this worker's PJRT runtime with bounded retries (transient
    /// driver/plugin hiccups are common on shared hosts); falls back to
    /// the stub runtime after the last attempt. Each failure is a
    /// structured trace event, not just a stderr line.
    fn load_runtime(&mut self) {
        let Some(dir) = self.shared.artifacts.clone() else { return };
        let retry = self.shared.cfg.fault.retry;
        for attempt in 0..retry.max_attempts.max(1) {
            match Runtime::load(&dir) {
                Ok(rt) => {
                    self.runtime = Some(rt);
                    return;
                }
                Err(e) => {
                    self.shared.trace(TraceEvent::RuntimeLoadFailed {
                        worker: self.id as u16,
                        attempt: (attempt + 1) as u16,
                        t: self.shared.now(),
                    });
                    if attempt + 1 >= retry.max_attempts.max(1) {
                        eprintln!(
                            "worker {}: PJRT load failed after {} attempts, \
                             falling back to stub runtime: {e:#}",
                            self.id,
                            attempt + 1
                        );
                    } else {
                        std::thread::sleep(Duration::from_micros(retry.backoff_us(attempt)));
                    }
                }
            }
        }
    }

    /// Failure detection, run on this worker's own push tick: any peer row
    /// stale past the heartbeat timeout is claimed dead under the SST lock
    /// (poisoning is idempotent, so exactly one detector wins the claim)
    /// and its orphaned tasks are re-placed. Only called when crash
    /// injection is configured — a real deployment would run it always,
    /// but here an unconditional detector could misfire on a slow CI host
    /// and perturb fault-free runs.
    fn detect_peers(&mut self, now: Micros) {
        let timeout = self.shared.cfg.fault.heartbeat_timeout_us;
        for p in 0..self.shared.cfg.n_workers {
            if p == self.id {
                continue;
            }
            let claimed = {
                let mut sst = lock_recover(&self.shared.sst);
                if sst.is_stale(p, now, timeout) {
                    sst.poison(p, now);
                    true
                } else {
                    false
                }
            };
            if claimed {
                self.recover_orphans(p, now);
            }
        }
    }

    /// Re-place every task owned by dead worker `p` that has not produced
    /// its output: collected from the shared job ledger (which stands in
    /// for Cascade object metadata — task outputs themselves are durable,
    /// so only unfinished tasks re-execute). Tasks merely *planned* onto
    /// `p` are rescued at assign time through the poisoned-row mask.
    fn recover_orphans(&mut self, p: WorkerId, now: Micros) {
        let sh = self.shared.clone();
        sh.faults_workers_failed.fetch_add(1, Ordering::Relaxed);
        sh.trace(TraceEvent::WorkerFailed {
            worker: p as u16,
            detector: self.id as u16,
            t: now,
        });
        // Collect under the jobs lock, re-place after dropping it
        // (assign_and_dispatch re-takes jobs; sst is never held here).
        let mut orphans: Vec<(usize, TaskId)> = Vec::new();
        {
            let mut jobs = lock_recover(&sh.jobs);
            for job_idx in 0..jobs.len() {
                let dfg = &sh.dfgs[jobs[job_idx].job.kind.index()];
                for t in 0..dfg.len() {
                    if jobs[job_idx].adfg.get(t) != Some(p)
                        || jobs[job_idx].output_worker[t].is_some()
                        || jobs[job_idx].remaining_preds[t] != 0
                    {
                        continue;
                    }
                    // Void the old transfers so re-dispatch re-requests
                    // every input from its durable holder.
                    jobs[job_idx].inputs_arrived[t] = 0;
                    for &pr in &dfg.preds[t] {
                        let slot =
                            dfg.succs[pr].iter().position(|&s| s == t).expect("edge");
                        jobs[job_idx].sent[pr][slot] = false;
                    }
                    orphans.push((job_idx, t));
                }
            }
        }
        for &(job_idx, t) in &orphans {
            self.assign_and_dispatch(job_idx, t);
        }
    }

    fn run(mut self, ready_tx: Sender<WorkerId>) -> WorkerMetrics {
        // Load this worker's own PJRT client + executables (not Send, so
        // construction must happen inside the thread).
        self.load_runtime();
        // Signal readiness; the leader resets the epoch once everyone is up.
        let _ = ready_tx.send(self.id);
        drop(ready_tx);
        let detect = self.shared.fault_plan.has_crashes();
        let push_wall = self.shared.to_wall(self.shared.cfg.push.load_interval_us);
        let mut next_push = Instant::now();
        loop {
            let now_p = self.shared.now();
            if !self.dead && self.crash_at.map_or(false, |t| now_p >= t) {
                // Silent failure: from here on the worker neither pushes
                // SST rows nor processes anything but Stop. Peers see the
                // row go stale and run recovery.
                self.dead = true;
            }
            // Rate-limited SST push on schedule (doubles as heartbeat).
            let now_wall = Instant::now();
            if !self.dead && now_wall >= next_push {
                self.push_sst(now_p);
                if detect {
                    self.detect_peers(now_p);
                }
                next_push = now_wall + push_wall;
            }
            let timeout = if self.dead {
                Duration::from_millis(50)
            } else {
                next_push.saturating_duration_since(Instant::now())
            };
            match self.rx.recv_timeout(timeout) {
                Ok(Msg::Stop) => break,
                Ok(_) if self.dead => {}
                Ok(Msg::Job { job_idx }) => self.handle_job(job_idx),
                Ok(Msg::Enqueue { job_idx, task }) => self.handle_enqueue(job_idx, task),
                Ok(Msg::Input { job_idx, task }) => {
                    lock_recover(&self.shared.jobs)[job_idx].inputs_arrived[task] += 1;
                    self.try_dispatch();
                }
                Ok(Msg::FetchDone { model }) => self.handle_fetch_done(model),
                Ok(Msg::ExecDone { job_idx, task }) => self.handle_exec_done(job_idx, task),
                Ok(Msg::BatchWindow { deadline }) => {
                    // Stale once the hold was satisfied or re-armed.
                    if self.hold_until == Some(deadline) {
                        self.hold_until = None;
                        self.dispatch(true);
                    }
                }
                Ok(Msg::BatchDone) => self.handle_batch_done(),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let span = self.shared.now();
        self.gpu.advance_time(span);
        // Hand this worker's cache event log to the shared tracer.
        if self.shared.cfg.trace.enabled {
            let events = self.gpu.drain_log();
            let mut tr = lock_recover(&self.shared.tracer);
            let worker = self.id as u16;
            for ev in events {
                let (model, free_bytes, t) = (ev.model, ev.free_bytes, ev.at_us);
                tr.record(match ev.kind {
                    CacheEventKind::Hit => TraceEvent::CacheHit { worker, model, free_bytes, t },
                    CacheEventKind::Miss => {
                        TraceEvent::CacheMiss { worker, model, free_bytes, t }
                    }
                    CacheEventKind::Insert => {
                        TraceEvent::CacheInsert { worker, model, free_bytes, t }
                    }
                    CacheEventKind::Evict => {
                        TraceEvent::CacheEvict { worker, model, free_bytes, t }
                    }
                });
            }
        }
        let s = self.gpu.stats;
        WorkerMetrics {
            busy_us: self.busy_us,
            hits: s.hits,
            misses: s.misses,
            fetches: s.fetches,
            evictions: s.evictions,
            cache_byte_time: s.byte_time_integral,
            gpu_capacity: self.gpu.capacity(),
            active: self.executed > 0,
        }
    }
}

/// Report from one live run.
pub struct LiveReport {
    pub metrics: MetricsSink,
    pub pjrt_executions: u64,
    pub mean_pjrt_exec_us: u64,
    /// Structured event trace; empty unless `cfg.trace.enabled`.
    pub trace: Trace,
}

pub struct LiveCluster;

impl LiveCluster {
    /// Run `jobs` through a live cluster; blocks until all complete (or the
    /// wall timeout trips, which is an error).
    pub fn run(
        cfg: ClusterConfig,
        live: LiveConfig,
        artifacts: Option<std::path::PathBuf>,
        jobs: Vec<Job>,
    ) -> Result<LiveReport> {
        let n_jobs = jobs.len();
        let n_workers = cfg.n_workers;
        let dfgs = pipelines::all(&cfg.cost);
        let scheduler = sched::build(&cfg);
        let speed: Vec<f64> = (0..n_workers).map(|w| cfg.speed(w)).collect();

        let live_jobs: Vec<LiveJob> = jobs
            .iter()
            .map(|j| {
                let dfg = &dfgs[j.kind.index()];
                let n = dfg.len();
                LiveJob {
                    job: j.clone(),
                    adfg: Adfg::unassigned(n),
                    inputs_arrived: vec![0; n],
                    remaining_preds: (0..n).map(|t| dfg.preds[t].len()).collect(),
                    output_worker: vec![None; n],
                    sent: (0..n).map(|t| vec![false; dfg.succs[t].len()]).collect(),
                    disrupted: false,
                }
            })
            .collect();

        let (net_tx, net_rx) = channel::<Parcel<Msg>>();
        let (done_tx, done_rx) = channel::<JobRecord>();
        let mut worker_txs = Vec::new();
        let mut worker_rxs = Vec::new();
        for _ in 0..n_workers {
            let (tx, rx) = channel::<Msg>();
            worker_txs.push(tx);
            worker_rxs.push(rx);
        }

        let fault_plan = FaultPlan::materialize(&cfg.fault, n_workers);
        let shared = Arc::new(Shared {
            speed,
            dfgs,
            scheduler,
            artifacts,
            sst: Mutex::new(Sst::new(n_workers)),
            jobs: Mutex::new(live_jobs),
            epoch: Mutex::new(Instant::now()),
            net_tx: net_tx.clone(),
            done_tx,
            pjrt_execs: AtomicU64::new(0),
            pjrt_exec_ns: AtomicU64::new(0),
            tracer: Mutex::new(Tracer::from_config(cfg.trace)),
            fault_plan,
            faults_workers_failed: AtomicU64::new(0),
            faults_tasks_re_placed: AtomicU64::new(0),
            faults_task_retries: AtomicU64::new(0),
            live,
            cfg,
        });

        // The fabric thread works in wall time; pre-scale the profiled
        // fault delays so the shim stays a plain `Micros` adder.
        let net_faults = shared.cfg.fault.net_faults().map(|mut nf| {
            nf.delay_us = (nf.delay_us as f64 / live.time_scale) as Micros;
            nf.retransmit_us = (nf.retransmit_us as f64 / live.time_scale) as Micros;
            nf
        });
        let fabric =
            std::thread::spawn(move || run_fabric_faults(net_rx, worker_txs.clone(), net_faults));

        let (ready_tx, ready_rx) = channel::<WorkerId>();
        let mut handles = Vec::new();
        let mut rng = Rng::new(shared.cfg.seed ^ 0x11fe);
        for (id, rx) in worker_rxs.into_iter().enumerate() {
            // WorkerNode is !Send (it owns PJRT handles), so it is
            // constructed inside its own thread from Send-able parts.
            let sh = shared.clone();
            let worker_rng = rng.fork();
            let rtx = ready_tx.clone();
            handles.push(std::thread::spawn(move || {
                // Fault state is read out of `sh` before the struct literal
                // moves it.
                let crash_at = sh.fault_plan.crash_at[id];
                let fault_rng = Rng::new(sh.cfg.fault.seed ^ 0xFA02 ^ (id as u64 + 1));
                let node = WorkerNode {
                    id,
                    gpu: {
                        let mut g = crate::gpu::GpuCache::new(sh.cfg.gpu_capacity, sh.cfg.eviction);
                        g.set_logging(sh.cfg.trace.enabled);
                        g
                    },
                    shared: sh,
                    runtime: None,
                    queue: Vec::new(),
                    running: Vec::new(),
                    exec_end: 0,
                    hold_until: None,
                    fetching: None,
                    busy_us: 0,
                    executed: 0,
                    rng: worker_rng,
                    rx,
                    scratch: PlanCell::default(),
                    crash_at,
                    dead: false,
                    fault_rng,
                    fetch_attempts: [0; crate::dfg::models::N_MODELS],
                };
                node.run(rtx)
            }));
        }
        drop(ready_tx);

        // Barrier: wait for every worker to finish its (possibly slow) PJRT
        // load, then reset profiled-time zero so startup isn't billed as
        // queueing delay. On failure the error names exactly which workers
        // never reported and keeps the underlying cause in the chain.
        let mut ready = vec![false; n_workers];
        for _ in 0..n_workers {
            match ready_rx.recv_timeout(live.wall_timeout) {
                Ok(w) => ready[w] = true,
                Err(e) => {
                    let missing: Vec<String> = ready
                        .iter()
                        .enumerate()
                        .filter(|&(_, &r)| !r)
                        .map(|(w, _)| w.to_string())
                        .collect();
                    return Err(anyhow::Error::new(e).context(format!(
                        "cluster startup: worker(s) [{}] failed to become ready within {:?}",
                        missing.join(", "),
                        live.wall_timeout
                    )));
                }
            }
        }
        *lock_recover(&shared.epoch) = Instant::now();

        // Client: replay arrivals on the scaled clock.
        {
            let sh = shared.clone();
            std::thread::spawn(move || {
                // Collect arrivals FIRST: holding the jobs lock across the
                // pacing sleeps below would stall every worker.
                let arrivals: Vec<Micros> = {
                    let jobs = lock_recover(&sh.jobs);
                    jobs.iter().map(|j| j.job.arrival_us).collect()
                };
                for (idx, arrival) in arrivals.into_iter().enumerate() {
                    let due = sh.to_wall(arrival);
                    let elapsed = lock_recover(&sh.epoch).elapsed();
                    if due > elapsed {
                        std::thread::sleep(due - elapsed);
                    }
                    let mut ingress = (hash_pair(idx as u64, 0x1693_55aa)
                        % sh.cfg.n_workers as u64)
                        as WorkerId;
                    // A real client whose ingress connection is refused
                    // retries the next worker; model that with the fault
                    // plan (the client "observes" the dead endpoint, it
                    // does not consult cluster state).
                    if sh.fault_plan.has_crashes() {
                        let now = sh.now();
                        for off in 0..sh.cfg.n_workers {
                            let w = (ingress + off) % sh.cfg.n_workers;
                            if sh.fault_plan.crash_at[w].map_or(true, |t| now < t) {
                                ingress = w;
                                break;
                            }
                        }
                    }
                    sh.send(ingress, 0, Msg::Job { job_idx: idx });
                }
            });
        }

        // Collect completions.
        let deadline = Instant::now() + live.wall_timeout;
        let mut records = Vec::with_capacity(n_jobs);
        let mut jobs_failed: u64 = 0;
        while records.len() < n_jobs {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                if !shared.fault_plan.has_crashes() {
                    anyhow::bail!("live run timed out with {}/{} jobs done", records.len(), n_jobs);
                }
                // Under crash injection a stall is a legitimate outcome
                // (e.g. every worker died): convert still-open jobs to
                // terminal `Failed` records instead of erroring out.
                while let Ok(r) = done_rx.try_recv() {
                    records.push(r);
                }
                let now = shared.now();
                {
                    let jobs = lock_recover(&shared.jobs);
                    for js in jobs.iter() {
                        let dfg = &shared.dfgs[js.job.kind.index()];
                        let exit = dfg.len() - 1;
                        if js.output_worker[exit].is_none() {
                            jobs_failed += 1;
                            records.push(JobRecord {
                                kind: js.job.kind,
                                arrival_us: js.job.arrival_us,
                                completion_us: now,
                                lower_bound_us: dfg.lower_bound_us,
                                outcome: JobOutcome::Failed,
                            });
                        }
                    }
                }
                // Absorb any completions that raced with the ledger scan.
                while records.len() < n_jobs {
                    match done_rx.recv_timeout(Duration::from_secs(1)) {
                        Ok(r) => records.push(r),
                        Err(_) => break,
                    }
                }
                break;
            }
            match done_rx.recv_timeout(left.min(Duration::from_millis(200))) {
                Ok(r) => records.push(r),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("workers died before completing the workload")
                }
            }
        }

        // Shut down.
        for w in 0..n_workers {
            shared.send(w, 0, Msg::Stop);
        }
        let worker_metrics: Vec<WorkerMetrics> = handles
            .into_iter()
            .enumerate()
            .map(|(w, h)| {
                h.join().unwrap_or_else(|_| {
                    eprintln!(
                        "coordinator: worker {w} thread panicked; reporting empty metrics for it"
                    );
                    WorkerMetrics::default()
                })
            })
            .collect();
        let pjrt_executions = shared.pjrt_execs.load(Ordering::Relaxed);
        let pjrt_ns = shared.pjrt_exec_ns.load(Ordering::Relaxed);
        let faults = FaultStats {
            workers_failed: shared.faults_workers_failed.load(Ordering::Relaxed),
            tasks_re_placed: shared.faults_tasks_re_placed.load(Ordering::Relaxed),
            task_retries: shared.faults_task_retries.load(Ordering::Relaxed),
            jobs_failed,
        };
        // All workers have joined (and drained their cache logs): the trace
        // is complete.
        let trace = lock_recover(&shared.tracer).take();
        drop(net_tx);
        drop(shared);
        let _ = fabric.join();

        let span = records.iter().map(|r| r.completion_us).max().unwrap_or(0);
        let metrics = MetricsSink {
            jobs: records,
            workers: worker_metrics,
            span_us: span,
            incomplete: 0,
            faults,
        };
        Ok(LiveReport {
            metrics,
            pjrt_executions,
            mean_pjrt_exec_us: pjrt_ns / 1000 / pjrt_executions.max(1),
            trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    #[test]
    fn live_cluster_completes_workload_without_runtime() {
        let cfg = ClusterConfig::default().with_seed(3);
        let live = LiveConfig { time_scale: 400.0, wall_timeout: Duration::from_secs(60) };
        let jobs = workload::poisson(2.0, 12, &[], 99);
        let rep = LiveCluster::run(cfg, live, None, jobs).unwrap();
        assert_eq!(rep.metrics.jobs.len(), 12);
        assert!(rep.metrics.mean_slowdown() >= 0.8);
        assert!(rep.metrics.cache_hit_rate() > 0.0);
    }

    #[test]
    fn live_cluster_produces_trace_when_enabled() {
        let mut cfg = ClusterConfig::default().with_seed(5);
        cfg.trace.enabled = true;
        let live = LiveConfig { time_scale: 400.0, wall_timeout: Duration::from_secs(60) };
        let jobs = workload::poisson(2.0, 8, &[], 21);
        let rep = LiveCluster::run(cfg, live, None, jobs).unwrap();
        assert_eq!(rep.metrics.jobs.len(), 8);
        assert_eq!(rep.trace.count(|e| matches!(e, TraceEvent::JobComplete { .. })), 8);
        assert!(rep.trace.count(|e| matches!(e, TraceEvent::Decision { .. })) > 0);
        assert!(!rep.trace.task_spans().is_empty());
        assert!(rep.trace.count(|e| matches!(e, TraceEvent::SstStaleness { .. })) > 0);
    }

    #[test]
    fn live_cluster_batches_same_model_load() {
        let mut cfg = ClusterConfig::default().with_seed(11).with_batching(4, 2_000);
        cfg.trace.enabled = true;
        let live = LiveConfig { time_scale: 400.0, wall_timeout: Duration::from_secs(60) };
        // All-VPA mix: every job funnels through the same two models, so
        // same-model queue-mates are common.
        let jobs = workload::poisson(4.0, 16, &[0.0, 0.0, 1.0, 0.0], 33);
        let rep = LiveCluster::run(cfg, live, None, jobs).unwrap();
        assert_eq!(rep.metrics.jobs.len(), 16);
        let formed = rep.trace.count(|e| matches!(e, TraceEvent::BatchFormed { .. }));
        let executed = rep.trace.count(|e| matches!(e, TraceEvent::BatchExecuted { .. }));
        assert!(formed > 0, "batching under same-model load must form batches");
        assert_eq!(formed, executed, "every formed batch retires exactly once");
    }

    #[test]
    fn live_cluster_all_schedulers() {
        use crate::config::SchedulerKind;
        for kind in SchedulerKind::ALL {
            let cfg = ClusterConfig::default().with_scheduler(kind).with_seed(4);
            let live = LiveConfig { time_scale: 500.0, wall_timeout: Duration::from_secs(60) };
            let jobs = workload::poisson(1.0, 6, &[], 7);
            let rep = LiveCluster::run(cfg, live, None, jobs).unwrap();
            assert_eq!(rep.metrics.jobs.len(), 6, "{kind:?}");
        }
    }

    #[test]
    fn live_cluster_recovers_from_worker_crash() {
        use crate::core::SEC;
        let mut cfg = ClusterConfig::default().with_seed(9);
        // One worker dies 2 virtual seconds in. The heartbeat timeout is
        // generous relative to the wall push cadence: at time_scale 100 it
        // is 100ms of wall silence, far past any scheduling jitter, so
        // only the genuinely dead worker is ever declared failed.
        cfg.fault.crashes = vec![(1, 2 * SEC)];
        cfg.fault.heartbeat_timeout_us = 10 * SEC;
        let live = LiveConfig { time_scale: 100.0, wall_timeout: Duration::from_secs(60) };
        let jobs = workload::poisson(2.0, 30, &[], 5);
        let rep = LiveCluster::run(cfg, live, None, jobs).unwrap();
        assert_eq!(rep.metrics.jobs.len(), 30, "every job reaches a terminal record");
        let faults = rep.metrics.faults;
        assert!(faults.workers_failed >= 1, "the crash must be detected: {faults:?}");
        assert!(faults.tasks_re_placed > 0, "orphans must be re-placed: {faults:?}");
        // > 96% allows at most one raced loss (a Job parcel in flight to
        // the dying worker at the crash instant is unrecoverable); the
        // common case is a clean 100%.
        assert!(
            rep.metrics.completion_rate() > 96.0,
            "one crash out of five workers must not fail jobs: rate={} faults={faults:?}",
            rep.metrics.completion_rate()
        );
    }
}
