//! Workflow Profiles Repository (paper §3.1).
//!
//! Holds the meta-information the scheduler's estimates are built from:
//! expected runtime costs R(t, ·) and input/output object sizes per DFG
//! vertex. The static values ship with the DFGs (profiled offline,
//! covering ≥95% of observed runs); this module adds the *online* half the
//! paper's Workflow Profiling component implies: every task completion
//! reports its actual runtime, and an exponentially-weighted moving
//! average refines the estimate — so mis-profiled workloads converge
//! toward accurate FT(w) predictions instead of misleading Algorithm 1
//! forever.

use crate::core::{Micros, TaskId};
use crate::dfg::{Dfg, PipelineKind};

/// EWMA-refined runtime profile for every (pipeline, task) pair.
#[derive(Debug, Clone)]
pub struct ProfileRepository {
    /// Smoothing factor for runtime updates (0 = frozen static profile,
    /// 1 = always trust the last observation).
    alpha: f64,
    /// estimates[kind][task] — current R(t) estimate, µs.
    estimates: Vec<Vec<f64>>,
    /// Observation counts, for diagnostics and convergence tests.
    observations: Vec<Vec<u64>>,
}

impl ProfileRepository {
    /// Seed from the static profiles attached to the DFGs.
    pub fn from_dfgs(dfgs: &[Dfg], alpha: f64) -> ProfileRepository {
        assert!((0.0..=1.0).contains(&alpha));
        ProfileRepository {
            alpha,
            estimates: dfgs
                .iter()
                .map(|d| d.vertices.iter().map(|v| v.mean_runtime_us as f64).collect())
                .collect(),
            observations: dfgs.iter().map(|d| vec![0; d.len()]).collect(),
        }
    }

    /// Current R(t) estimate for a task, µs.
    pub fn runtime(&self, kind: PipelineKind, t: TaskId) -> Micros {
        self.estimates[kind.index()][t] as Micros
    }

    /// Record an observed runtime and refine the estimate.
    pub fn observe(&mut self, kind: PipelineKind, t: TaskId, actual_us: Micros) {
        let e = &mut self.estimates[kind.index()][t];
        *e = (1.0 - self.alpha) * *e + self.alpha * actual_us as f64;
        self.observations[kind.index()][t] += 1;
    }

    pub fn observations(&self, kind: PipelineKind, t: TaskId) -> u64 {
        self.observations[kind.index()][t]
    }

    /// Write the refined estimates back into a set of DFGs (e.g. before
    /// persisting, or to re-rank with converged profiles).
    pub fn apply_to(&self, dfgs: &mut [Dfg]) {
        for d in dfgs.iter_mut() {
            let k = d.kind.index();
            for v in d.vertices.iter_mut() {
                v.mean_runtime_us = self.estimates[k][v.id] as Micros;
            }
        }
    }

    /// Mean relative error of the current estimates against a ground-truth
    /// oracle (testing/diagnostics).
    pub fn mean_rel_error(&self, truth: &dyn Fn(PipelineKind, TaskId) -> Micros) -> f64 {
        let mut total = 0.0;
        let mut n = 0usize;
        for kind in PipelineKind::ALL {
            for (t, e) in self.estimates[kind.index()].iter().enumerate() {
                let tr = truth(kind, t) as f64;
                if tr > 0.0 {
                    total += (e - tr).abs() / tr;
                    n += 1;
                }
            }
        }
        total / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MS;
    use crate::dfg::pipelines;
    use crate::net::CostModel;
    use crate::util::rng::Rng;

    fn repo(alpha: f64) -> ProfileRepository {
        ProfileRepository::from_dfgs(&pipelines::all(&CostModel::default()), alpha)
    }

    #[test]
    fn seeds_from_static_profiles() {
        let r = repo(0.2);
        let dfg = pipelines::vpa(&CostModel::default());
        for v in &dfg.vertices {
            assert_eq!(r.runtime(PipelineKind::Vpa, v.id), v.mean_runtime_us);
        }
    }

    #[test]
    fn alpha_zero_freezes_estimates() {
        let mut r = repo(0.0);
        let before = r.runtime(PipelineKind::Vpa, 0);
        r.observe(PipelineKind::Vpa, 0, 10 * before);
        assert_eq!(r.runtime(PipelineKind::Vpa, 0), before);
    }

    #[test]
    fn converges_to_shifted_truth() {
        // The workload actually runs 2x slower than profiled: the EWMA must
        // converge there.
        let mut r = repo(0.2);
        let truth = 2 * r.runtime(PipelineKind::Translation, 0);
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let sample = rng.jitter(truth as f64, 0.1, 1.0) as Micros;
            r.observe(PipelineKind::Translation, 0, sample);
        }
        let est = r.runtime(PipelineKind::Translation, 0);
        let rel = (est as f64 - truth as f64).abs() / truth as f64;
        assert!(rel < 0.1, "est {est} vs truth {truth}");
        assert_eq!(r.observations(PipelineKind::Translation, 0), 200);
    }

    #[test]
    fn apply_to_updates_dfgs_and_error_metric() {
        let cost = CostModel::default();
        let mut dfgs = pipelines::all(&cost);
        let mut r = ProfileRepository::from_dfgs(&dfgs, 0.5);
        for _ in 0..50 {
            r.observe(PipelineKind::Vpa, 0, 2000 * MS);
        }
        r.apply_to(&mut dfgs);
        let updated = dfgs[PipelineKind::Vpa.index()].vertices[0].mean_runtime_us;
        assert!(updated > 1900 * MS, "apply_to didn't persist: {updated}");

        let statics = pipelines::all(&cost);
        let err = r.mean_rel_error(&|k: PipelineKind, t: TaskId| {
            statics[k.index()].vertices[t].mean_runtime_us
        });
        assert!(err > 0.0 && err < 1.0);
    }
}
