//! Cascade-like object store substrate (§5, §5.1.2).
//!
//! Objects are variable-length byte vectors named by path; each has a small
//! set of home servers chosen by randomized hash placement within shards of
//! size 2–3. Access is free on a home server; otherwise a network transfer
//! is charged per the Fig. 4 cost model. The live coordinator stores ML
//! model blobs and intermediate outputs here; the scheduler consumes only
//! the access-cost estimates.

use crate::core::{fnv1a, Micros, WorkerId};
use crate::net::CostModel;
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct StoredObject {
    pub bytes: u64,
    pub payload: Option<Vec<u8>>,
}

#[derive(Debug)]
pub struct ObjectStore {
    n_workers: usize,
    shard_size: usize,
    objects: HashMap<String, StoredObject>,
}

impl ObjectStore {
    pub fn new(n_workers: usize, shard_size: usize) -> ObjectStore {
        assert!(shard_size >= 1);
        ObjectStore { n_workers, shard_size: shard_size.min(n_workers), objects: HashMap::new() }
    }

    /// Home nodes for a key: `shard_size` distinct workers from the key hash
    /// (Cascade's randomized hash-based placement).
    pub fn home_nodes(&self, key: &str) -> Vec<WorkerId> {
        let h = fnv1a(key.as_bytes());
        let mut homes = Vec::with_capacity(self.shard_size);
        let mut i = 0u64;
        while homes.len() < self.shard_size {
            let w = (crate::core::hash_pair(h, i) % self.n_workers as u64) as WorkerId;
            if !homes.contains(&w) {
                homes.push(w);
            }
            i += 1;
        }
        homes
    }

    pub fn is_home(&self, key: &str, w: WorkerId) -> bool {
        self.home_nodes(key).contains(&w)
    }

    pub fn put(&mut self, key: &str, bytes: u64, payload: Option<Vec<u8>>) {
        self.objects.insert(key.to_string(), StoredObject { bytes, payload });
    }

    pub fn get(&self, key: &str) -> Option<&StoredObject> {
        self.objects.get(key)
    }

    /// Estimated access cost from worker `from` (Fig. 4): free if local
    /// (home node), one network transfer otherwise.
    pub fn access_cost(&self, key: &str, from: WorkerId, cost: &CostModel) -> Option<Micros> {
        let obj = self.objects.get(key)?;
        Some(if self.is_home(key, from) { 0 } else { cost.td_transfer(obj.bytes) })
    }

    pub fn len(&self) -> usize {
        self.objects.len()
    }

    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MB;

    #[test]
    fn home_nodes_distinct_and_in_range() {
        let s = ObjectStore::new(6, 3);
        for key in ["a", "model/opt", "job/42/out"] {
            let homes = s.home_nodes(key);
            assert_eq!(homes.len(), 3);
            let mut uniq = homes.clone();
            uniq.dedup();
            uniq.sort();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "{homes:?}");
            assert!(homes.iter().all(|&w| w < 6));
        }
    }

    #[test]
    fn placement_deterministic() {
        let s = ObjectStore::new(8, 2);
        assert_eq!(s.home_nodes("k"), s.home_nodes("k"));
    }

    #[test]
    fn placement_spreads_keys() {
        let s = ObjectStore::new(8, 2);
        let mut hit = vec![false; 8];
        for i in 0..200 {
            for w in s.home_nodes(&format!("key-{i}")) {
                hit[w] = true;
            }
        }
        assert!(hit.iter().all(|&h| h), "some worker never a home: {hit:?}");
    }

    #[test]
    fn access_free_on_home() {
        let mut s = ObjectStore::new(4, 2);
        s.put("obj", 10 * MB, None);
        let cost = CostModel::default();
        let homes = s.home_nodes("obj");
        assert_eq!(s.access_cost("obj", homes[0], &cost), Some(0));
        let other = (0..4).find(|w| !homes.contains(w)).unwrap();
        assert!(s.access_cost("obj", other, &cost).unwrap() > 0);
    }

    #[test]
    fn missing_object_is_none() {
        let s = ObjectStore::new(4, 2);
        assert_eq!(s.access_cost("nope", 0, &CostModel::default()), None);
    }

    #[test]
    fn shard_size_clamped_to_cluster() {
        let s = ObjectStore::new(2, 3);
        assert_eq!(s.home_nodes("x").len(), 2);
    }
}
