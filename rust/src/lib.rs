//! # Compass (a.k.a. Navigator) — decentralized scheduling for
//! latency-sensitive ML workflows
//!
//! A full reproduction of *"Navigator: A Decentralized Scheduler for
//! Latency-Sensitive ML Workflows"*: the scheduler (planning +
//! dynamic-adjustment phases), GPU-memory-as-model-cache management with
//! FIFO and queue-lookahead eviction, the SST-based decentralized state
//! monitor with bounded staleness, the three baseline schedulers the paper
//! compares against, a validated discrete-event simulator, a live
//! multi-worker coordinator executing real AOT-compiled models through
//! PJRT, and an experiment harness regenerating every table and figure of
//! the paper's evaluation. See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod config;
pub mod coordinator;
pub mod core;
pub mod dfg;
pub mod exp;
pub mod fault;
pub mod gpu;
pub mod lint;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod profiles;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sst;
pub mod store;
pub mod util;
pub mod workload;

pub use config::{ClusterConfig, CompassConfig, SchedulerKind};
pub use dfg::{Adfg, Dfg, Job, PipelineKind};
pub use sim::{SimReport, Simulator};
