//! Deterministic PRNG + distributions (in-tree replacement for `rand`).
//!
//! SplitMix64 core: tiny, fast, passes BigCrush for this purpose, and —
//! critically for the experiment harness — bit-for-bit reproducible across
//! runs and platforms. All simulator randomness flows through this type so
//! every figure in EXPERIMENTS.md regenerates identically.

/// SplitMix64 PRNG (Steele, Lea, Flood 2014).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Derive an independent child stream (for per-worker / per-component RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Exponential with the given rate (mean 1/rate). Used for Poisson
    /// inter-arrival times.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Truncated normal sample around `mean` with relative std `rel_std`,
    /// clamped to [mean*(1-3r), mean*(1+3r)] and at least `floor`.
    /// Models per-instance runtime / object-size variation (paper §2.2:
    /// profiles cover 95% of observed data).
    pub fn jitter(&mut self, mean: f64, rel_std: f64, floor: f64) -> f64 {
        let v = mean * (1.0 + rel_std * self.normal());
        v.clamp((mean * (1.0 - 3.0 * rel_std)).max(floor), mean * (1.0 + 3.0 * rel_std))
    }

    /// Pick a uniformly random element index weighted by `weights`.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(8);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn jitter_respects_floor_and_band() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.jitter(100.0, 0.1, 1.0);
            assert!(v >= 70.0 - 1e-9 && v <= 130.0 + 1e-9, "v={v}");
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork();
        let mut b = root.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
