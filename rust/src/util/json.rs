//! Minimal JSON parser (in-tree replacement for `serde_json`).
//!
//! Full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null) — enough to read `artifacts/manifest.json` and any config
//! files. Not performance-critical: parsing happens once at startup.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

/// Serializer: compact JSON, object keys in `BTreeMap` order (stable
/// output for artifacts diffed across runs). Non-finite numbers have no
/// JSON spelling and are written as `null`.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) if x.is_finite() => write!(f, "{x}"),
            Json::Num(_) => f.write_str("null"),
            Json::Str(s) => write!(f, "\"{}\"", escape(s)),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "\"{}\":{v}", escape(k))?;
                }
                f.write_str("}")
            }
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("invalid utf-8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

/// Minimal JSON writer (for experiment result dumps).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"opt": {"model_id": 0, "seq_len": 32, "path": "opt.hlo.txt",
                        "smoke_output_abssum": 123.5}}"#,
        )
        .unwrap();
        let m = j.get("opt").unwrap();
        assert_eq!(m.get("model_id").unwrap().as_u64(), Some(0));
        assert_eq!(m.get("path").unwrap().as_str(), Some("opt.hlo.txt"));
        assert!(m.get("smoke_output_abssum").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
    }

    #[test]
    fn escape_roundtrip() {
        let s = "line\n\"quoted\"\\tab\t";
        let parsed = Json::parse(&format!("\"{}\"", escape(s))).unwrap();
        assert_eq!(parsed, Json::Str(s.into()));
    }

    #[test]
    fn display_roundtrips_through_parser() {
        let j = Json::parse(r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": false, "d": null}"#).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn display_maps_nonfinite_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(42.0).to_string(), "42");
    }
}
