//! Descriptive statistics for experiment reporting: percentiles, box-plot
//! summaries (matching the paper's Figure 6 box semantics), and means.

use std::cmp::Ordering;

/// Canonical total-order comparison for `f64` (lint rule L5). IEEE-754
/// total order: every float (including NaN) sorts deterministically, so
/// scoring and percentile sorts can never panic or diverge between runs.
/// All scheduler tie-breaks and stat sorts must route through this helper
/// instead of raw `partial_cmp().unwrap()`.
pub fn cmp_f64(a: f64, b: f64) -> Ordering {
    a.total_cmp(&b)
}

/// Five-number box-plot summary plus whiskers as drawn in the paper's
/// Figure 6: box = [Q1, Q3], whiskers at 1.5 IQR, the rest outliers.
#[derive(Debug, Clone, PartialEq)]
pub struct BoxStats {
    pub n: usize,
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub whisker_lo: f64,
    pub whisker_hi: f64,
    pub outliers: usize,
    pub mean: f64,
}

/// Linear-interpolation percentile on a *sorted* slice, p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of an unsorted sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| cmp_f64(*a, *b));
    percentile_sorted(&v, p)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

impl BoxStats {
    pub fn from(xs: &[f64]) -> BoxStats {
        assert!(!xs.is_empty(), "BoxStats of empty sample");
        let mut v = xs.to_vec();
        v.sort_by(|a, b| cmp_f64(*a, *b));
        let q1 = percentile_sorted(&v, 25.0);
        let q3 = percentile_sorted(&v, 75.0);
        let iqr = q3 - q1;
        let lo_fence = q1 - 1.5 * iqr;
        let hi_fence = q3 + 1.5 * iqr;
        let whisker_lo = v.iter().copied().find(|&x| x >= lo_fence).unwrap_or(v[0]);
        let whisker_hi = v.iter().rev().copied().find(|&x| x <= hi_fence).unwrap_or(v[v.len() - 1]);
        let outliers = v.iter().filter(|&&x| x < whisker_lo || x > whisker_hi).count();
        BoxStats {
            n: v.len(),
            min: v[0],
            q1,
            median: percentile_sorted(&v, 50.0),
            q3,
            max: v[v.len() - 1],
            whisker_lo,
            whisker_hi,
            outliers,
            mean: mean(&v),
        }
    }

    /// One-line rendering for experiment tables.
    pub fn render(&self) -> String {
        format!(
            "n={:<5} min={:7.2} q1={:7.2} med={:7.2} q3={:7.2} max={:8.2} out={}",
            self.n, self.min, self.q1, self.median, self.q3, self.max, self.outliers
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
        assert_eq!(percentile(&v, 25.0), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile(&v, 75.0) - 7.5).abs() < 1e-12);
    }

    #[test]
    fn box_stats_quartiles_ordered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let b = BoxStats::from(&xs);
        assert!(b.min <= b.q1 && b.q1 <= b.median && b.median <= b.q3 && b.q3 <= b.max);
        assert_eq!(b.n, 100);
        assert_eq!(b.outliers, 0);
    }

    #[test]
    fn box_stats_detects_outlier() {
        let mut xs: Vec<f64> = (0..99).map(|i| 1.0 + (i as f64) * 0.01).collect();
        xs.push(1000.0);
        let b = BoxStats::from(&xs);
        assert!(b.outliers >= 1);
        assert!(b.whisker_hi < 1000.0);
    }

    #[test]
    fn mean_median_single() {
        assert_eq!(mean(&[4.0]), 4.0);
        assert_eq!(median(&[4.0]), 4.0);
    }

    #[test]
    fn cmp_f64_totally_orders_nan() {
        let mut v = [2.0, f64::NAN, 1.0, -0.0, 0.0];
        v.sort_by(|a, b| cmp_f64(*a, *b));
        assert_eq!(v[0], -0.0);
        assert_eq!(v[2], 1.0);
        assert!(v[4].is_nan()); // NaN sorts last, deterministically
        assert_eq!(cmp_f64(1.0, 1.0), Ordering::Equal);
        assert_eq!(cmp_f64(1.0, 2.0), Ordering::Less);
    }
}
