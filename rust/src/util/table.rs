//! Plain-text table rendering for experiment output (paper-style rows).

/// Render rows as an aligned ASCII table with a header rule.
pub fn render(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push_str("  ");
            }
            line.push_str(&format!("{:<width$}", c, width = widths[i]));
        }
        line.trim_end().to_string()
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let t = render(
            &["scheduler", "latency"],
            &[
                vec!["compass".into(), "2.5".into()],
                vec!["jit".into(), "5.0".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines[0].starts_with("scheduler"));
        assert!(lines[1].starts_with("---"));
        assert_eq!(lines.len(), 4);
    }
}
