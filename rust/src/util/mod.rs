//! In-tree substrate utilities.
//!
//! The build environment is fully offline with only `xla` + `anyhow`
//! vendored, so the support crates a project like this would normally pull
//! in (rand, serde_json, clap, criterion, proptest) are implemented here
//! from scratch: a deterministic RNG with the distributions the workload
//! generators need, a minimal JSON parser for the artifact manifest, a
//! stats/percentile kit, a tiny argv parser, and a property-test driver.

pub mod args;
pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
