//! Property-test driver (in-tree replacement for `proptest`).
//!
//! Runs a property closure over N seeded random cases; on failure it
//! reports the failing seed so the case can be replayed exactly:
//! no shrinking, but full reproducibility.

use super::rng::Rng;

/// Number of cases per property (override with COMPASS_PROP_CASES).
pub fn cases() -> u64 {
    std::env::var("COMPASS_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `cases()` RNG streams derived from `seed_base`.
/// The closure returns `Err(msg)` to fail the property.
pub fn check<F>(name: &str, seed_base: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases() {
        let seed = seed_base
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true() {
        check("tautology", 1, |_rng| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always-false'")]
    fn fails_loudly() {
        check("always-false", 2, |_rng| Err("nope".into()));
    }

    #[test]
    fn rng_streams_vary_across_cases() {
        use std::cell::RefCell;
        let seen = RefCell::new(std::collections::HashSet::new());
        check("distinct-streams", 3, |rng| {
            seen.borrow_mut().insert(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.borrow().len() as u64, cases());
    }
}
