//! Micro/endto-end bench harness (in-tree replacement for `criterion`).
//!
//! `cargo bench` invokes our `harness = false` bench binaries, which drive
//! this module: warmup, timed iterations, and a median/mean/p95 report in a
//! stable single-line format that EXPERIMENTS.md quotes directly.

use std::time::{Duration, Instant};

pub struct Bench {
    name: String,
    warmup: Duration,
    min_iters: u32,
    target: Duration,
}

#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(200),
            min_iters: 10,
            target: Duration::from_secs(2),
        }
    }

    pub fn quick(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(20),
            min_iters: 3,
            target: Duration::from_millis(400),
        }
    }

    /// Time `f` repeatedly; the closure should return something observable
    /// (guards against the optimizer deleting the work).
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> BenchReport {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed samples.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples_ns.len() < self.min_iters as usize || start.elapsed() < self.target {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() >= 100_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let report = BenchReport {
            name: self.name.clone(),
            iters: n as u32,
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            median_ns: samples_ns[n / 2],
            p95_ns: samples_ns[((n as f64 * 0.95) as usize).min(n - 1)],
            min_ns: samples_ns[0],
        };
        println!("{}", report.render());
        report
    }
}

impl BenchReport {
    pub fn render(&self) -> String {
        format!(
            "bench {:<44} iters={:<7} median={:>12} mean={:>12} p95={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let r = Bench::quick("noop").run(|| 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.median_ns >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(10.0).ends_with("ns"));
        assert!(fmt_ns(10_000.0).ends_with("µs"));
        assert!(fmt_ns(10_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with(" s"));
    }
}
