//! Micro/endto-end bench harness (in-tree replacement for `criterion`).
//!
//! `cargo bench` invokes our `harness = false` bench binaries, which drive
//! this module: warmup, timed iterations, and a median/mean/p95 report in a
//! stable single-line format that EXPERIMENTS.md quotes directly.

use std::time::{Duration, Instant};

pub struct Bench {
    name: String,
    warmup: Duration,
    min_iters: u32,
    target: Duration,
}

#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    /// Derived throughput for benches with a natural event count
    /// (simulator runs); `None` for pure-latency micro benches.
    pub events_per_sec: Option<f64>,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(200),
            min_iters: 10,
            target: Duration::from_secs(2),
        }
    }

    pub fn quick(name: &str) -> Bench {
        Bench {
            name: name.to_string(),
            warmup: Duration::from_millis(20),
            min_iters: 3,
            target: Duration::from_millis(400),
        }
    }

    /// Time `f` repeatedly; the closure should return something observable
    /// (guards against the optimizer deleting the work).
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> BenchReport {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Timed samples.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples_ns.len() < self.min_iters as usize || start.elapsed() < self.target {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() >= 100_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| crate::util::stats::cmp_f64(*a, *b));
        let n = samples_ns.len();
        let report = BenchReport {
            name: self.name.clone(),
            iters: n as u32,
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            median_ns: samples_ns[n / 2],
            p95_ns: samples_ns[((n as f64 * 0.95) as usize).min(n - 1)],
            min_ns: samples_ns[0],
            events_per_sec: None,
        };
        println!("{}", report.render());
        report
    }
}

impl BenchReport {
    pub fn render(&self) -> String {
        format!(
            "bench {:<44} iters={:<7} median={:>12} mean={:>12} p95={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
        )
    }

    /// Derive throughput from the events one iteration processes.
    pub fn with_events(mut self, events_per_iter: u64) -> BenchReport {
        if self.median_ns > 0.0 {
            self.events_per_sec = Some(events_per_iter as f64 * 1e9 / self.median_ns);
        }
        self
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("median_ns".to_string(), Json::Num(self.median_ns));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("p95_ns".to_string(), Json::Num(self.p95_ns));
        m.insert("min_ns".to_string(), Json::Num(self.min_ns));
        m.insert(
            "events_per_sec".to_string(),
            self.events_per_sec.map(Json::Num).unwrap_or(Json::Null),
        );
        Json::Obj(m)
    }
}

/// Write bench reports to a JSON array file (`cargo bench -- --json
/// BENCH_sim.json`). Merges by bench name with any existing file so the
/// separate bench binaries accumulate into one artifact.
pub fn write_json(path: &std::path::Path, reports: &[BenchReport]) -> std::io::Result<()> {
    use crate::util::json::Json;
    let mut entries: Vec<Json> = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_arr().map(|a| a.to_vec()))
        .unwrap_or_default();
    for r in reports {
        let j = r.to_json();
        let slot = entries
            .iter_mut()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some(r.name.as_str()));
        match slot {
            Some(e) => *e = j,
            None => entries.push(j),
        }
    }
    let mut out = String::from("[\n");
    for (i, e) in entries.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str("  ");
        out.push_str(&e.to_string());
    }
    out.push_str("\n]\n");
    std::fs::write(path, out)
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let r = Bench::quick("noop").run(|| 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.median_ns >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(10.0).ends_with("ns"));
        assert!(fmt_ns(10_000.0).ends_with("µs"));
        assert!(fmt_ns(10_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with(" s"));
    }

    fn report(name: &str, median_ns: f64) -> BenchReport {
        BenchReport {
            name: name.to_string(),
            iters: 10,
            mean_ns: median_ns,
            median_ns,
            p95_ns: median_ns,
            min_ns: median_ns,
            events_per_sec: None,
        }
    }

    #[test]
    fn with_events_derives_throughput() {
        let r = report("sim", 2_000_000.0).with_events(10_000);
        // 10k events / 2 ms = 5M events/s.
        assert_eq!(r.events_per_sec, Some(5_000_000.0));
    }

    #[test]
    fn json_has_required_fields() {
        let j = report("x", 1234.0).with_events(100).to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("median_ns").unwrap().as_f64(), Some(1234.0));
        assert!(j.get("events_per_sec").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn write_json_merges_by_name() {
        let path = std::env::temp_dir().join(format!("bench_merge_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        write_json(&path, &[report("a", 1.0), report("b", 2.0)]).unwrap();
        // Second write updates "b" and adds "c".
        write_json(&path, &[report("b", 20.0), report("c", 3.0)]).unwrap();
        let j = crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        let by_name = |n: &str| {
            arr.iter()
                .find(|e| e.get("name").and_then(|x| x.as_str()) == Some(n))
                .and_then(|e| e.get("median_ns").unwrap().as_f64())
                .unwrap()
        };
        assert_eq!(by_name("a"), 1.0);
        assert_eq!(by_name("b"), 20.0);
        assert_eq!(by_name("c"), 3.0);
        let _ = std::fs::remove_file(&path);
    }
}
