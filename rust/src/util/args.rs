//! Tiny argv parser (in-tree replacement for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argv entries (excluding the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// An option interpreted as a filesystem path (e.g. `--trace-out FILE`).
    pub fn get_path(&self, name: &str) -> Option<std::path::PathBuf> {
        self.get(name).map(std::path::PathBuf::from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = args(&["experiment", "fig6a", "--seed", "42", "--workers=7", "--verbose"]);
        assert_eq!(a.positional, vec!["experiment", "fig6a"]);
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get_usize("workers", 0), 7);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = args(&["--fast", "--out", "x.txt"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("out"), Some("x.txt"));
    }

    #[test]
    fn defaults() {
        let a = args(&[]);
        assert_eq!(a.get_f64("rate", 2.0), 2.0);
        assert_eq!(a.get_or("mode", "sim"), "sim");
    }

    #[test]
    fn path_options() {
        let a = args(&["--trace-out", "out/trace.json"]);
        assert_eq!(a.get_path("trace-out"), Some(std::path::PathBuf::from("out/trace.json")));
        assert_eq!(a.get_path("metrics-out"), None);
    }
}
