//! Configuration system: cluster, scheduler, and workload parameters.
//!
//! Every experiment is a `ClusterConfig` + a workload; the CLI and the
//! experiment harness build these programmatically, and `from_kv_file`
//! loads a simple `key = value` config file (TOML-subset) for deployments.

use crate::core::{Micros, GB, MS};
use crate::fault::FaultConfig;
use crate::gpu::EvictionPolicy;
use crate::net::CostModel;
use crate::obs::TraceConfig;
use crate::sst::PushConfig;
use std::path::Path;

/// Which scheduler drives task placement (§6.2.1 baselines + Compass).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedulerKind {
    Compass,
    Jit,
    Heft,
    Hash,
}

impl SchedulerKind {
    pub const ALL: [SchedulerKind; 4] =
        [SchedulerKind::Compass, SchedulerKind::Jit, SchedulerKind::Heft, SchedulerKind::Hash];

    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::Compass => "compass",
            SchedulerKind::Jit => "jit",
            SchedulerKind::Heft => "heft",
            SchedulerKind::Hash => "hash",
        }
    }

    pub fn parse(s: &str) -> Option<SchedulerKind> {
        match s.to_ascii_lowercase().as_str() {
            "compass" | "navigator" => Some(SchedulerKind::Compass),
            "jit" => Some(SchedulerKind::Jit),
            "heft" => Some(SchedulerKind::Heft),
            "hash" => Some(SchedulerKind::Hash),
            _ => None,
        }
    }
}

/// Compass-specific knobs, including the §6.3 ablation switches.
#[derive(Debug, Clone, Copy)]
pub struct CompassConfig {
    /// Enable the dynamic adjustment phase (Algorithm 2). Ablation:
    /// "dynamic task scheduling".
    pub dynamic_adjust: bool,
    /// Consider peers' GPU cache contents in TD_model estimates (Eq. 2).
    /// Ablation: "model locality".
    pub model_locality: bool,
    /// Algorithm 2 line 2: reschedule when FT(w) > R(t,w) * threshold.
    pub adjust_threshold: f64,
    /// Eq. 2 third arm: added cost estimate when placing a model on a
    /// worker whose cache would need an eviction, as a multiple of the
    /// mean model fetch time.
    pub eviction_penalty_factor: f64,
}

impl Default for CompassConfig {
    fn default() -> Self {
        CompassConfig {
            dynamic_adjust: true,
            model_locality: true,
            adjust_threshold: 2.0,
            eviction_penalty_factor: 1.0,
        }
    }
}

/// Full cluster + scheduling configuration for one run.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub n_workers: usize,
    /// GPU Navigator-cache capacity per worker (T4: 16 GB, §6).
    pub gpu_capacity: u64,
    /// Relative execution speed per worker (R(t,w) = R(t) * speed[w]);
    /// empty = homogeneous 1.0.
    pub worker_speed: Vec<f64>,
    pub cost: CostModel,
    pub scheduler: SchedulerKind,
    pub compass: CompassConfig,
    pub eviction: EvictionPolicy,
    pub push: PushConfig,
    /// Relative std-dev of per-instance runtime jitter (§3.2: actual
    /// runtimes are unpredictable; profiles are means).
    pub runtime_jitter: f64,
    /// True-runtime multiplier vs the static profiles (models a
    /// mis-profiled deployment: actual work is `bias ×` what the profile
    /// repository claims). 1.0 = accurately profiled.
    pub runtime_bias: f64,
    /// EWMA smoothing for the online Workflow Profiles Repository
    /// (§3.1); 0 disables refinement (estimates stay static).
    pub profile_alpha: f64,
    /// Straggler injection (fault model for the §3.2 "unpredictable
    /// runtimes" claim): each task independently becomes a straggler with
    /// this probability, running `straggler_factor ×` its sampled runtime.
    pub straggler_prob: f64,
    /// Runtime multiplier for injected stragglers.
    pub straggler_factor: f64,
    pub seed: u64,
    /// Structured event tracing (see `obs`); disabled by default so the
    /// hot paths pay only a branch.
    pub trace: TraceConfig,
    /// Fault injection + recovery (DESIGN.md §9); fully disabled by
    /// default, in which case the whole subsystem is inert.
    pub fault: FaultConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        // The paper's testbed: 5 workers, 16 GB T4 per worker.
        ClusterConfig {
            n_workers: 5,
            gpu_capacity: 16 * GB,
            worker_speed: Vec::new(),
            cost: CostModel::default(),
            scheduler: SchedulerKind::Compass,
            compass: CompassConfig::default(),
            eviction: EvictionPolicy::default(),
            push: PushConfig::default(),
            runtime_jitter: 0.10,
            runtime_bias: 1.0,
            profile_alpha: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 4.0,
            seed: 0xC0FFEE,
            trace: TraceConfig::default(),
            fault: FaultConfig::default(),
        }
    }
}

impl ClusterConfig {
    pub fn with_scheduler(mut self, s: SchedulerKind) -> Self {
        self.scheduler = s;
        self
    }

    pub fn with_workers(mut self, n: usize) -> Self {
        self.n_workers = n;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_batching(mut self, batch_max: usize, window_us: Micros) -> Self {
        self.cost.batch.batch_max = batch_max.max(1);
        self.cost.batch.window_us = window_us;
        self
    }

    pub fn speed(&self, w: usize) -> f64 {
        self.worker_speed.get(w).copied().unwrap_or(1.0)
    }

    /// Load `key = value` lines (a TOML subset: comments with '#',
    /// strings unquoted or double-quoted, numbers, bools).
    pub fn from_kv_file(path: &Path) -> anyhow::Result<ClusterConfig> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = ClusterConfig::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let k = k.trim();
            let v = v.trim().trim_matches('"');
            match k {
                "workers" => cfg.n_workers = v.parse()?,
                "gpu_capacity_gb" => cfg.gpu_capacity = v.parse::<u64>()? * GB,
                "scheduler" => {
                    cfg.scheduler = SchedulerKind::parse(v)
                        .ok_or_else(|| anyhow::anyhow!("unknown scheduler '{v}'"))?
                }
                "dynamic_adjust" => cfg.compass.dynamic_adjust = v.parse()?,
                "model_locality" => cfg.compass.model_locality = v.parse()?,
                "adjust_threshold" => cfg.compass.adjust_threshold = v.parse()?,
                "eviction_penalty_factor" => cfg.compass.eviction_penalty_factor = v.parse()?,
                "eviction" => {
                    cfg.eviction = match v {
                        "fifo" => EvictionPolicy::Fifo,
                        "lookahead" => EvictionPolicy::default(),
                        other => anyhow::bail!("unknown eviction policy '{other}'"),
                    }
                }
                "lookahead_window" => {
                    cfg.eviction = EvictionPolicy::QueueLookahead { window: v.parse()? }
                }
                "push_interval_ms" => {
                    let us: Micros = v.parse::<u64>()? * MS;
                    cfg.push = PushConfig { load_interval_us: us, cache_interval_us: us };
                }
                "load_push_interval_ms" => cfg.push.load_interval_us = v.parse::<u64>()? * MS,
                "cache_push_interval_ms" => cfg.push.cache_interval_us = v.parse::<u64>()? * MS,
                "batch_max" => cfg.cost.batch.batch_max = v.parse()?,
                "batch_window_us" => cfg.cost.batch.window_us = v.parse()?,
                "batch_alpha" => cfg.cost.batch.alpha_override = Some(v.parse()?),
                "runtime_jitter" => cfg.runtime_jitter = v.parse()?,
                "runtime_bias" => cfg.runtime_bias = v.parse()?,
                "profile_alpha" => cfg.profile_alpha = v.parse()?,
                "straggler_prob" => cfg.straggler_prob = v.parse()?,
                "straggler_factor" => cfg.straggler_factor = v.parse()?,
                "seed" => cfg.seed = v.parse()?,
                "trace" => cfg.trace.enabled = v.parse()?,
                "trace_capacity" => cfg.trace.capacity = v.parse()?,
                "fault_crash_rate" => cfg.fault.crash_rate = v.parse()?,
                "fault_crash" => cfg.fault.crashes = crate::fault::parse_crash_spec(v)?,
                "fault_crash_window_ms" => {
                    cfg.fault.crash_window_us = v.parse::<u64>()? * MS
                }
                "fault_slowdown_rate" => cfg.fault.slowdown_rate = v.parse()?,
                "fault_slowdown_factor" => cfg.fault.slowdown_factor = v.parse()?,
                "fault_slowdown_ms" => cfg.fault.slowdown_us = v.parse::<u64>()? * MS,
                "fault_drop_prob" => cfg.fault.drop_prob = v.parse()?,
                "fault_delay_prob" => cfg.fault.delay_prob = v.parse()?,
                "fault_delay_ms" => cfg.fault.delay_us = v.parse::<u64>()? * MS,
                "fault_fetch_fail_prob" => cfg.fault.fetch_fail_prob = v.parse()?,
                "fault_retry_attempts" => cfg.fault.retry.max_attempts = v.parse()?,
                "fault_retry_backoff_ms" => {
                    cfg.fault.retry.backoff_base_us = v.parse::<u64>()? * MS
                }
                "fault_heartbeat_timeout_ms" => {
                    cfg.fault.heartbeat_timeout_us = v.parse::<u64>()? * MS
                }
                "fault_seed" => cfg.fault.seed = v.parse()?,
                other => anyhow::bail!("line {}: unknown key '{other}'", lineno + 1),
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn default_matches_paper_testbed() {
        let c = ClusterConfig::default();
        assert_eq!(c.n_workers, 5);
        assert_eq!(c.gpu_capacity, 16 * GB);
        assert_eq!(c.scheduler, SchedulerKind::Compass);
    }

    #[test]
    fn scheduler_parse_aliases() {
        assert_eq!(SchedulerKind::parse("navigator"), Some(SchedulerKind::Compass));
        assert_eq!(SchedulerKind::parse("HEFT"), Some(SchedulerKind::Heft));
        assert_eq!(SchedulerKind::parse("bogus"), None);
    }

    #[test]
    fn kv_file_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("compass_cfg_{}.toml", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        writeln!(
            f,
            "# test config\nworkers = 7\nscheduler = \"jit\"\n\
             gpu_capacity_gb = 24\npush_interval_ms = 100\nseed = 9"
        )
        .unwrap();
        let c = ClusterConfig::from_kv_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(c.n_workers, 7);
        assert_eq!(c.scheduler, SchedulerKind::Jit);
        assert_eq!(c.gpu_capacity, 24 * GB);
        assert_eq!(c.push.load_interval_us, 100_000);
        assert_eq!(c.seed, 9);
    }

    #[test]
    fn kv_file_rejects_unknown_key() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("compass_badcfg_{}.toml", std::process::id()));
        std::fs::write(&path, "frobnicate = 3\n").unwrap();
        let err = ClusterConfig::from_kv_file(&path).unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(err.to_string().contains("unknown key"));
    }

    #[test]
    fn kv_file_batching_keys() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("compass_batchcfg_{}.toml", std::process::id()));
        std::fs::write(&path, "batch_max = 8\nbatch_window_us = 500\nbatch_alpha = 0.4\n")
            .unwrap();
        let c = ClusterConfig::from_kv_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(c.cost.batch.batch_max, 8);
        assert_eq!(c.cost.batch.window_us, 500);
        assert_eq!(c.cost.batch.alpha_override, Some(0.4));
        assert!(c.cost.batch.enabled());
    }

    #[test]
    fn kv_file_fault_keys() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("compass_faultcfg_{}.toml", std::process::id()));
        std::fs::write(
            &path,
            "fault_crash_rate = 0.25\nfault_crash = \"0@1500,2@3000\"\n\
             fault_heartbeat_timeout_ms = 900\nfault_fetch_fail_prob = 0.1\n\
             fault_retry_attempts = 5\nfault_retry_backoff_ms = 20\nfault_seed = 77\n",
        )
        .unwrap();
        let c = ClusterConfig::from_kv_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(c.fault.crash_rate, 0.25);
        assert_eq!(c.fault.crashes, vec![(0, 1500 * MS), (2, 3000 * MS)]);
        assert_eq!(c.fault.heartbeat_timeout_us, 900 * MS);
        assert_eq!(c.fault.fetch_fail_prob, 0.1);
        assert_eq!(c.fault.retry.max_attempts, 5);
        assert_eq!(c.fault.retry.backoff_base_us, 20 * MS);
        assert_eq!(c.fault.seed, 77);
        assert!(c.fault.enabled());
        assert!(!ClusterConfig::default().fault.enabled());
    }

    #[test]
    fn speed_defaults_homogeneous() {
        let c = ClusterConfig::default();
        assert_eq!(c.speed(0), 1.0);
        assert_eq!(c.speed(4), 1.0);
    }
}
