//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` produced by
//! `python/compile/aot.py`) and execute them from the rust hot path.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. Each model is compiled once at load; execution
//! clones no weights (they are baked into the executable as constants).
//!
//! A numerical handshake runs at load: the manifest carries the abs-sum of
//! a deterministic smoke input/output pair computed by jax, and we re-run
//! the same pair through the compiled executable — any mismatch between the
//! python and rust halves fails loudly at startup rather than silently
//! serving wrong numbers.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Metadata for one compiled model (from artifacts/manifest.json).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub model_id: u8,
    pub seq_len: usize,
    pub d_model: usize,
    pub path: PathBuf,
    pub smoke_input_abssum: f64,
    pub smoke_output_abssum: f64,
}

/// A loaded, compiled model executable.
pub struct CompiledModel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledModel {
    /// Execute the forward pass on a [seq_len * d_model] f32 activation.
    pub fn execute(&self, input: &[f32]) -> Result<Vec<f32>> {
        let (s, d) = (self.meta.seq_len, self.meta.d_model);
        if input.len() != s * d {
            bail!("input len {} != {}x{}", input.len(), s, d);
        }
        let lit = xla::Literal::vec1(input).reshape(&[s as i64, d as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// The deterministic smoke input python used (sin(0.01 * i)).
    pub fn smoke_input(&self) -> Vec<f32> {
        let n = self.meta.seq_len * self.meta.d_model;
        (0..n).map(|i| ((i as f32) * 0.01).sin()).collect()
    }

    /// Re-run the python-side smoke pair; error if abs-sums diverge.
    pub fn handshake(&self) -> Result<()> {
        let x = self.smoke_input();
        let in_abssum: f64 = x.iter().map(|v| v.abs() as f64).sum();
        if (in_abssum - self.meta.smoke_input_abssum).abs() > 1e-2 {
            bail!(
                "{}: smoke input mismatch rust={} python={}",
                self.meta.name,
                in_abssum,
                self.meta.smoke_input_abssum
            );
        }
        let y = self.execute(&x)?;
        let out_abssum: f64 = y.iter().map(|v| v.abs() as f64).sum();
        let rel = (out_abssum - self.meta.smoke_output_abssum).abs()
            / self.meta.smoke_output_abssum.max(1e-9);
        if rel > 1e-3 {
            bail!(
                "{}: smoke output mismatch rust={} python={} (rel {rel})",
                self.meta.name,
                out_abssum,
                self.meta.smoke_output_abssum
            );
        }
        Ok(())
    }
}

/// The model registry: every artifact compiled on one PJRT CPU client.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    models: HashMap<String, CompiledModel>,
    by_id: HashMap<u8, String>,
}

impl Runtime {
    /// Parse artifacts/manifest.json into metadata entries.
    pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts`)", mpath.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let obj = json.as_obj().ok_or_else(|| anyhow!("manifest not an object"))?;
        let mut metas = Vec::new();
        for (name, m) in obj {
            let get =
                |k: &str| m.get(k).ok_or_else(|| anyhow!("manifest[{name}] missing '{k}'"));
            metas.push(ArtifactMeta {
                name: name.clone(),
                model_id: get("model_id")?.as_u64().unwrap_or(255) as u8,
                seq_len: get("seq_len")?.as_u64().unwrap_or(0) as usize,
                d_model: get("d_model")?.as_u64().unwrap_or(0) as usize,
                path: dir
                    .join(get("path")?.as_str().ok_or_else(|| anyhow!("path not a string"))?),
                smoke_input_abssum: get("smoke_input_abssum")?
                    .as_f64()
                    .ok_or_else(|| anyhow!("bad smoke_input_abssum"))?,
                smoke_output_abssum: get("smoke_output_abssum")?
                    .as_f64()
                    .ok_or_else(|| anyhow!("bad smoke_output_abssum"))?,
            });
        }
        Ok(metas)
    }

    /// Load and compile every artifact WITHOUT handshakes (diagnostics).
    pub fn load_unchecked(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let metas = Self::read_manifest(dir)?;
        let mut models = HashMap::new();
        let mut by_id = HashMap::new();
        for meta in metas {
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            by_id.insert(meta.model_id, meta.name.clone());
            models.insert(meta.name.clone(), CompiledModel { meta, exe });
        }
        Ok(Runtime { client, models, by_id })
    }

    /// Load and compile every artifact in `dir`; run handshakes.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let metas = Self::read_manifest(dir)?;
        let mut models = HashMap::new();
        let mut by_id = HashMap::new();
        for meta in metas {
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let model = CompiledModel { meta: meta.clone(), exe };
            model.handshake().with_context(|| format!("handshake failed for {}", meta.name))?;
            by_id.insert(meta.model_id, meta.name.clone());
            models.insert(meta.name.clone(), model);
        }
        Ok(Runtime { client, models, by_id })
    }

    pub fn get(&self, name: &str) -> Option<&CompiledModel> {
        self.models.get(name)
    }

    pub fn get_by_id(&self, id: u8) -> Option<&CompiledModel> {
        self.by_id.get(&id).and_then(|n| self.models.get(n))
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.models.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }
}

/// Default artifacts directory: $COMPASS_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("COMPASS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
