//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` produced by
//! `python/compile/aot.py`) and execute them from the rust hot path.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. Each model is compiled once at load; execution
//! clones no weights (they are baked into the executable as constants).
//!
//! A numerical handshake runs at load: the manifest carries the abs-sum of
//! a deterministic smoke input/output pair computed by jax, and we re-run
//! the same pair through the compiled executable — any mismatch between the
//! python and rust halves fails loudly at startup rather than silently
//! serving wrong numbers.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Metadata for one compiled model (from artifacts/manifest.json).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub model_id: u8,
    pub seq_len: usize,
    pub d_model: usize,
    /// Largest batch the compiled executable accepts in one call. 1 (the
    /// default when the manifest omits the key) means the artifact was
    /// AOT-compiled for a single `[seq_len, d_model]` activation and
    /// batched execution must fall back to one call per member.
    pub batch_max: usize,
    pub path: PathBuf,
    pub smoke_input_abssum: f64,
    pub smoke_output_abssum: f64,
}

/// A loaded, compiled model executable.
pub struct CompiledModel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledModel {
    /// Execute the forward pass on a [seq_len * d_model] f32 activation.
    pub fn execute(&self, input: &[f32]) -> Result<Vec<f32>> {
        let (s, d) = (self.meta.seq_len, self.meta.d_model);
        if input.len() != s * d {
            bail!("input len {} != {}x{}", input.len(), s, d);
        }
        let lit = xla::Literal::vec1(input).reshape(&[s as i64, d as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute a coalesced batch of forward passes. When the manifest marks
    /// this artifact batch-capable (`batch_max > 1`) and the batch fits,
    /// all members are stacked into one `[batch_max, seq_len*d_model]`
    /// activation and run as a single PJRT call (short batches are
    /// zero-padded; padded rows are discarded). Otherwise each member runs
    /// through its own `execute` call — same results, no stacking.
    pub fn execute_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let row = self.meta.seq_len * self.meta.d_model;
        for x in inputs {
            if x.len() != row {
                bail!(
                    "batch input len {} != {}x{}",
                    x.len(),
                    self.meta.seq_len,
                    self.meta.d_model
                );
            }
        }
        if inputs.len() <= 1 || self.meta.batch_max < inputs.len() {
            return inputs.iter().map(|x| self.execute(x)).collect();
        }
        let b = self.meta.batch_max;
        let mut flat = vec![0f32; b * row];
        for (i, x) in inputs.iter().enumerate() {
            flat[i * row..(i + 1) * row].copy_from_slice(x);
        }
        let lit = xla::Literal::vec1(&flat).reshape(&[b as i64, row as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?.to_vec::<f32>()?;
        let mut rows = split_rows(out, b);
        rows.truncate(inputs.len());
        Ok(rows)
    }

    /// The deterministic smoke input python used (sin(0.01 * i)).
    pub fn smoke_input(&self) -> Vec<f32> {
        let n = self.meta.seq_len * self.meta.d_model;
        (0..n).map(|i| ((i as f32) * 0.01).sin()).collect()
    }

    /// Re-run the python-side smoke pair; error if abs-sums diverge.
    pub fn handshake(&self) -> Result<()> {
        let x = self.smoke_input();
        let in_abssum: f64 = x.iter().map(|v| v.abs() as f64).sum();
        if (in_abssum - self.meta.smoke_input_abssum).abs() > 1e-2 {
            bail!(
                "{}: smoke input mismatch rust={} python={}",
                self.meta.name,
                in_abssum,
                self.meta.smoke_input_abssum
            );
        }
        let y = self.execute(&x)?;
        let out_abssum: f64 = y.iter().map(|v| v.abs() as f64).sum();
        let rel = (out_abssum - self.meta.smoke_output_abssum).abs()
            / self.meta.smoke_output_abssum.max(1e-9);
        if rel > 1e-3 {
            bail!(
                "{}: smoke output mismatch rust={} python={} (rel {rel})",
                self.meta.name,
                out_abssum,
                self.meta.smoke_output_abssum
            );
        }
        Ok(())
    }
}

/// The model registry: every artifact compiled on one PJRT CPU client.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    models: HashMap<String, CompiledModel>,
    by_id: HashMap<u8, String>,
}

impl Runtime {
    /// Parse artifacts/manifest.json into metadata entries.
    pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts`)", mpath.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let obj = json.as_obj().ok_or_else(|| anyhow!("manifest not an object"))?;
        let mut metas = Vec::new();
        for (name, m) in obj {
            let get =
                |k: &str| m.get(k).ok_or_else(|| anyhow!("manifest[{name}] missing '{k}'"));
            metas.push(ArtifactMeta {
                name: name.clone(),
                model_id: get("model_id")?.as_u64().unwrap_or(255) as u8,
                seq_len: get("seq_len")?.as_u64().unwrap_or(0) as usize,
                d_model: get("d_model")?.as_u64().unwrap_or(0) as usize,
                batch_max: m
                    .get("batch_max")
                    .and_then(|v| v.as_u64())
                    .unwrap_or(1)
                    .max(1) as usize,
                path: dir
                    .join(get("path")?.as_str().ok_or_else(|| anyhow!("path not a string"))?),
                smoke_input_abssum: get("smoke_input_abssum")?
                    .as_f64()
                    .ok_or_else(|| anyhow!("bad smoke_input_abssum"))?,
                smoke_output_abssum: get("smoke_output_abssum")?
                    .as_f64()
                    .ok_or_else(|| anyhow!("bad smoke_output_abssum"))?,
            });
        }
        Ok(metas)
    }

    /// Load and compile every artifact WITHOUT handshakes (diagnostics).
    pub fn load_unchecked(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let metas = Self::read_manifest(dir)?;
        let mut models = HashMap::new();
        let mut by_id = HashMap::new();
        for meta in metas {
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            by_id.insert(meta.model_id, meta.name.clone());
            models.insert(meta.name.clone(), CompiledModel { meta, exe });
        }
        Ok(Runtime { client, models, by_id })
    }

    /// Load and compile every artifact in `dir`; run handshakes.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let metas = Self::read_manifest(dir)?;
        let mut models = HashMap::new();
        let mut by_id = HashMap::new();
        for meta in metas {
            let proto = xla::HloModuleProto::from_text_file(
                meta.path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let model = CompiledModel { meta: meta.clone(), exe };
            model.handshake().with_context(|| format!("handshake failed for {}", meta.name))?;
            by_id.insert(meta.model_id, meta.name.clone());
            models.insert(meta.name.clone(), model);
        }
        Ok(Runtime { client, models, by_id })
    }

    pub fn get(&self, name: &str) -> Option<&CompiledModel> {
        self.models.get(name)
    }

    pub fn get_by_id(&self, id: u8) -> Option<&CompiledModel> {
        self.by_id.get(&id).and_then(|n| self.models.get(n))
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.models.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }
}

/// Split a flat stacked output into `rows` equal per-member chunks.
fn split_rows(flat: Vec<f32>, rows: usize) -> Vec<Vec<f32>> {
    if rows <= 1 {
        return vec![flat];
    }
    let per = (flat.len() / rows).max(1);
    flat.chunks(per).take(rows).map(|c| c.to_vec()).collect()
}

/// Default artifacts directory: $COMPASS_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("COMPASS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_rows_partitions_evenly() {
        let rows = split_rows((0..12).map(|v| v as f32).collect(), 3);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(rows[2][0], 8.0);
    }

    #[test]
    fn split_rows_single_is_identity() {
        let flat: Vec<f32> = vec![1.0, 2.0];
        assert_eq!(split_rows(flat.clone(), 1), vec![flat]);
    }

    #[test]
    fn manifest_batch_max_defaults_to_one() {
        let dir = std::env::temp_dir().join(format!("compass-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"opt": {"model_id": 0, "seq_len": 4, "d_model": 8, "path": "opt.hlo.txt",
                 "smoke_input_abssum": 1.0, "smoke_output_abssum": 2.0},
                "bart": {"model_id": 5, "seq_len": 4, "d_model": 8, "batch_max": 4,
                 "path": "bart.hlo.txt", "smoke_input_abssum": 1.0,
                 "smoke_output_abssum": 2.0}}"#,
        )
        .unwrap();
        let mut metas = Runtime::read_manifest(&dir).unwrap();
        metas.sort_by(|a, b| a.name.cmp(&b.name));
        assert_eq!(metas.len(), 2);
        assert_eq!(metas[0].name, "bart");
        assert_eq!(metas[0].batch_max, 4);
        assert_eq!(metas[1].name, "opt");
        assert_eq!(metas[1].batch_max, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
