//! Discrete-event simulator (paper §5.4).
//!
//! Models exactly the elements of §3: per-worker execution queues, the task
//! dispatcher loop (skipping tasks whose inputs or models aren't ready),
//! GPU model fetches over PCIe with cache eviction, ADFG dispatch and
//! intermediate-output transfers over the network, and rate-limited SST
//! pushes. Events are processed in simulated-time order with a
//! deterministic tiebreaker, so every run is bit-reproducible from its
//! seed. The paper validated its simulator within 5% of the real system at
//! 5 workers; `compass validate` repeats that comparison against our live
//! coordinator (see `exp::validate`).

mod queue;
mod worker;

pub use queue::EventQueue;
pub use worker::{QTask, SimWorker};

use crate::config::ClusterConfig;
use crate::core::{hash_pair, Micros, ModelId, TaskId, WorkerId};
use crate::dfg::models::{model_bytes, N_MODELS};
use crate::dfg::{pipelines, Adfg, Dfg, Job};
use crate::fault::{FaultPlan, NetFaults};
use crate::gpu::CacheEventKind;
use crate::metrics::{FaultStats, JobOutcome, JobRecord, MetricsSink, WorkerMetrics};
use crate::obs::{SchedPhase, Trace, TraceEvent, Tracer};
use crate::profiles::ProfileRepository;
use crate::sched::{self, AssignCtx, ClusterView, DecisionProbe, PlanCell, Scheduler};
use crate::sst::{Sst, SstRow};
use crate::util::rng::Rng;

/// Salt for the client's ingress-worker choice.
const INGRESS_SALT: u64 = 0x1693_55aa;

/// Simulation events. Queue ordering is (time, seq): simultaneous events
/// process deterministically in creation order — the ordering lives in
/// [`EventQueue`]'s index heap, so the payload needs no `Ord`.
#[derive(Debug, Clone, Copy)]
enum Event {
    JobArrival { job_idx: usize },
    /// ADFG message lands at `w`: task joins its execution queue.
    TaskEnqueue { w: WorkerId, job_idx: usize, task: TaskId },
    /// One input object for (job, task) landed at the assigned worker.
    /// `gen` is the placement generation the transfer was addressed to;
    /// a mismatch against [`JobState::placement_gen`] means the task was
    /// re-placed while the bytes were in flight, and the arrival is void.
    InputArrive { job_idx: usize, task: TaskId, gen: u32 },
    /// PCIe fetch of `model` finished on `w`.
    FetchDone { w: WorkerId, model: ModelId },
    /// Task execution finished on `w`.
    ExecDone { w: WorkerId, job_idx: usize, task: TaskId },
    /// Batch-window hold expired on `w`: start whatever coalesced. Stale
    /// once the worker's hold deadline no longer matches (a batch already
    /// started); then it is ignored.
    BatchWindow { w: WorkerId, deadline: Micros },
    /// Batch execution finished on `w`: retire every member.
    BatchDone { w: WorkerId },
    /// Rate-limited SST pushes (§5.2); separate load/cache timers (Fig. 8).
    PushLoad { w: WorkerId },
    PushCache { w: WorkerId },
    /// Fault injection: worker `w` fails silently at this instant. The
    /// event only *silences* the worker (its queue and running work stop
    /// making progress, its SST pushes cease); peers discover the failure
    /// later through SST staleness and run recovery then, so detection
    /// latency is modeled, not assumed away.
    WorkerCrash { w: WorkerId },
}

/// Per-job bookkeeping during simulation. Every vector is pre-sized from
/// the DFG at construction, and the layout is flat: the per-edge `sent`
/// flags live in one vector indexed through `Simulator::succ_off` (edge
/// `p → succs[p][slot]` is bit `succ_off[p] + slot`) instead of a
/// vec-of-vecs, so a job costs 6 allocations instead of 7 + one per task.
struct JobState {
    job: Job,
    adfg: Adfg,
    /// Arrived-input counters per task (entry counts the client input).
    inputs_arrived: Vec<usize>,
    remaining_preds: Vec<usize>,
    /// Worker holding each task's output once done. A task is done exactly
    /// when its output has a holder (see [`JobState::done`]).
    output_worker: Vec<Option<WorkerId>>,
    /// Flat per-edge output-sent flags; see `Simulator::succ_off`.
    sent: Vec<bool>,
    /// Per-task placement generation. Bumped when a task is re-placed
    /// after a worker failure so in-flight [`Event::InputArrive`] events
    /// addressed to the old placement are recognized as stale and dropped.
    placement_gen: Vec<u32>,
    /// True once any task of this job was re-placed by failure recovery;
    /// the job then completes as [`JobOutcome::Degraded`].
    disrupted: bool,
    completed: bool,
}

impl JobState {
    fn new(job: Job, dfg: &Dfg) -> JobState {
        let n = dfg.len();
        let edges: usize = dfg.succs.iter().map(|s| s.len()).sum();
        JobState {
            job,
            adfg: Adfg::unassigned(n),
            inputs_arrived: vec![0; n],
            remaining_preds: (0..n).map(|t| dfg.preds[t].len()).collect(),
            output_worker: vec![None; n],
            sent: vec![false; edges],
            placement_gen: vec![0; n],
            disrupted: false,
            completed: false,
        }
    }

    fn needed_inputs(&self, dfg: &Dfg, t: TaskId) -> usize {
        dfg.preds[t].len().max(1) // entry waits for the client input
    }

    #[inline]
    fn done(&self, t: TaskId) -> bool {
        self.output_worker[t].is_some()
    }
}

/// Result of one simulation run.
#[derive(Debug)]
pub struct SimReport {
    pub metrics: MetricsSink,
    pub events_processed: u64,
    pub sim_span_us: Micros,
    /// Structured event trace; empty unless `cfg.trace.enabled`.
    pub trace: Trace,
}

pub struct Simulator {
    cfg: ClusterConfig,
    dfgs: Vec<Dfg>,
    scheduler: Box<dyn Scheduler>,
    workers: Vec<SimWorker>,
    sst: Sst,
    jobs: Vec<JobState>,
    queue: EventQueue<Event>,
    now: Micros,
    completed_jobs: usize,
    records: Vec<JobRecord>,
    speed: Vec<f64>,
    rows_scratch: Vec<SstRow>,
    /// Ground-truth mean runtimes (static profile × runtime_bias): what
    /// tasks *actually* take, as opposed to what the profiles claim.
    true_runtimes: Vec<Vec<f64>>,
    /// Online Workflow Profiles Repository (§3.1); None when static.
    profiles: Option<ProfileRepository>,
    events_processed: u64,
    tracer: Tracer,
    /// Per-kind edge offsets into `JobState::sent`: edge `p → succs[p][slot]`
    /// of kind `k` is flag `succ_off[k][p] + slot`. The succs *topology*
    /// never changes (profiles only refine runtimes), so this is computed
    /// once.
    succ_off: Vec<Vec<usize>>,
    /// Reusable planning scratch shared with the scheduler through
    /// `ClusterView` — plan/assign allocate nothing per job.
    plan_scratch: PlanCell,
    /// Hot-path scratch, reused across all events of a run (taken with
    /// `mem::take`, refilled, and restored; never freed).
    pred_buf: Vec<(WorkerId, u64)>,
    preds_buf: Vec<TaskId>,
    succs_buf: Vec<TaskId>,
    lookahead_buf: Vec<ModelId>,
    /// Queue indices of the forming batch (dispatch scan scratch).
    members_buf: Vec<usize>,
    /// Retired batch members awaiting successor processing.
    done_buf: Vec<QTask>,
    /// Materialized fault schedule (empty ⇒ every fault path is inert and
    /// the run is byte-identical to a fault-free build).
    fault_plan: FaultPlan,
    /// Network-fault shim for cross-worker messages; None when disabled.
    net_faults: Option<NetFaults>,
    /// RNG for online fault draws (fetch failures). Seeded from
    /// `cfg.fault.seed ^ 0xFA02`, never from the workload seed, so fault
    /// draws don't perturb workload randomness.
    fault_rng: Rng,
    /// Ground-truth crash flags, set the instant `WorkerCrash` fires —
    /// before any peer has *detected* the failure via SST staleness.
    crashed: Vec<bool>,
    /// Instant each worker crashed (busy-time accounting stops there).
    crash_at_us: Vec<Micros>,
    alive_workers: usize,
    /// Consecutive-failure counters per (worker, model) fetch, flat-indexed
    /// `w * N_MODELS + m`; reset on success or on hitting the retry cap.
    fetch_attempts: Vec<u32>,
    fault_stats: FaultStats,
    /// Tasks drained off a dead worker, awaiting re-placement (reused).
    orphan_buf: Vec<QTask>,
}

impl Simulator {
    pub fn new(cfg: ClusterConfig) -> Simulator {
        let dfgs = pipelines::all(&cfg.cost);
        let scheduler = sched::build(&cfg);
        let mut rng = Rng::new(cfg.seed);
        let workers: Vec<SimWorker> =
            (0..cfg.n_workers).map(|id| SimWorker::new(id, &cfg, rng.fork())).collect();
        let speed: Vec<f64> = (0..cfg.n_workers).map(|w| cfg.speed(w)).collect();
        let true_runtimes: Vec<Vec<f64>> = dfgs
            .iter()
            .map(|d| {
                d.vertices.iter().map(|v| v.mean_runtime_us as f64 * cfg.runtime_bias).collect()
            })
            .collect();
        let profiles = (cfg.profile_alpha > 0.0)
            .then(|| ProfileRepository::from_dfgs(&dfgs, cfg.profile_alpha));
        let succ_off: Vec<Vec<usize>> = dfgs
            .iter()
            .map(|d| {
                let mut off = Vec::with_capacity(d.len());
                let mut acc = 0usize;
                for t in 0..d.len() {
                    off.push(acc);
                    acc += d.succs[t].len();
                }
                off
            })
            .collect();
        let fault_plan = FaultPlan::materialize(&cfg.fault, cfg.n_workers);
        Simulator {
            sst: Sst::new(cfg.n_workers),
            dfgs,
            scheduler,
            workers,
            jobs: Vec::new(),
            queue: EventQueue::new(),
            now: 0,
            completed_jobs: 0,
            records: Vec::new(),
            speed,
            rows_scratch: Vec::with_capacity(cfg.n_workers),
            true_runtimes,
            profiles,
            events_processed: 0,
            tracer: Tracer::from_config(cfg.trace),
            succ_off,
            plan_scratch: PlanCell::default(),
            pred_buf: Vec::new(),
            preds_buf: Vec::new(),
            succs_buf: Vec::new(),
            lookahead_buf: Vec::new(),
            members_buf: Vec::new(),
            done_buf: Vec::new(),
            fault_plan,
            net_faults: cfg.fault.net_faults(),
            fault_rng: Rng::new(cfg.fault.seed ^ 0xFA02),
            crashed: vec![false; cfg.n_workers],
            crash_at_us: vec![0; cfg.n_workers],
            alive_workers: cfg.n_workers,
            fetch_attempts: vec![0; cfg.n_workers * N_MODELS],
            fault_stats: FaultStats::default(),
            orphan_buf: Vec::new(),
            cfg,
        }
    }

    /// Extra network delay for a `from → to` message under the fault
    /// shim. Local messages never touch the network (and draw nothing);
    /// without a shim this is free and drawless, keeping fault-free runs
    /// byte-identical.
    #[inline]
    fn net_extra(&mut self, from: WorkerId, to: WorkerId) -> Micros {
        if from == to {
            return 0;
        }
        match &mut self.net_faults {
            Some(nf) => nf.extra_delay_us(),
            None => 0,
        }
    }

    /// First non-crashed worker at or after `from` in ring order. Uses
    /// ground truth (not SST poison state): it models a *client* retrying
    /// until a connection is accepted, which needs no failure detector.
    fn first_alive(&self, from: WorkerId) -> Option<WorkerId> {
        let n = self.cfg.n_workers;
        for i in 0..n {
            let c = (from + i) % n;
            if !self.crashed[c] {
                return Some(c);
            }
        }
        None
    }

    fn push_event(&mut self, at: Micros, ev: Event) {
        self.queue.push(at, ev);
    }

    /// Published rows with the deciding worker's own row refreshed live
    /// (a worker always knows its own state, §3.4).
    /// Fills the reusable scratch buffer (one allocation for the whole
    /// run; this copy happens on every scheduling decision). Free function
    /// over disjoint fields so callers can keep borrowing `self`.
    fn fill_view_rows(
        scratch: &mut Vec<SstRow>,
        sst: &Sst,
        workers: &[SimWorker],
        now: Micros,
        self_w: WorkerId,
        batch: &crate::net::BatchConfig,
    ) {
        scratch.clear();
        scratch.extend_from_slice(sst.rows());
        scratch[self_w] = workers[self_w].live_row(now, batch);
    }

    fn view_rows(&mut self, self_w: WorkerId) {
        Self::fill_view_rows(
            &mut self.rows_scratch,
            &self.sst,
            &self.workers,
            self.now,
            self_w,
            &self.cfg.cost.batch,
        );
    }

    /// Run `scheduler.assign` for a task that just became dispatchable on
    /// `on_worker`, then dispatch the ADFG message and the input transfers.
    fn assign_and_dispatch(&mut self, job_idx: usize, task: TaskId, on_worker: WorkerId) {
        self.view_rows(on_worker);
        let mut probe =
            if self.tracer.on() { DecisionProbe::on() } else { DecisionProbe::off() };
        // Input locations go into a buffer reused across all dispatches —
        // taken out of `self` so the scheduler call can borrow the rest.
        let mut pred_outputs = std::mem::take(&mut self.pred_buf);
        pred_outputs.clear();
        {
            let js = &self.jobs[job_idx];
            let dfg = &self.dfgs[js.job.kind.index()];
            if dfg.preds[task].is_empty() {
                pred_outputs.push((on_worker, js.job.input_bytes));
            } else {
                for &p in &dfg.preds[task] {
                    pred_outputs.push((
                        js.output_worker[p].expect("pred done"),
                        dfg.vertices[p].output_bytes,
                    ));
                }
            }
        }
        let planned_before = self.jobs[job_idx].adfg.get(task);
        let target = {
            let js = &self.jobs[job_idx];
            let dfg = &self.dfgs[js.job.kind.index()];
            let view = ClusterView {
                now: self.now,
                self_worker: on_worker,
                rows: &self.rows_scratch,
                cost: &self.cfg.cost,
                speed: &self.speed,
                scratch: &self.plan_scratch,
            };
            let ctx = AssignCtx {
                job: &js.job,
                dfg,
                task,
                planned: planned_before,
                pred_outputs: &pred_outputs,
            };
            self.scheduler.assign_probed(&ctx, &view, &mut probe)
        };

        if probe.is_active() {
            self.tracer.record(TraceEvent::Decision {
                job: self.jobs[job_idx].job.id,
                task: task as u16,
                phase: SchedPhase::Adjust,
                decider: on_worker as u16,
                chosen: target as u16,
                candidates: probe.take_single(),
                t: self.now,
            });
        }

        // A placement pointing at a worker declared dead means this assign
        // IS a recovery re-placement (Algorithm 2 with the poisoned row
        // masked). Account for it centrally: queue-drain recovery, late
        // ADFG messages, and pinned joins rescued at assign time all pass
        // through here.
        let re_placed =
            planned_before.map_or(false, |p| self.sst.rows()[p].poisoned());
        if re_placed {
            self.jobs[job_idx].disrupted = true;
            self.fault_stats.tasks_re_placed += 1;
            if self.tracer.on() {
                self.tracer.record(TraceEvent::TaskRePlaced {
                    job: self.jobs[job_idx].job.id,
                    task: task as u16,
                    from: planned_before.unwrap_or(on_worker) as u16,
                    to: target as u16,
                    t: self.now,
                });
            }
        }

        self.jobs[job_idx].adfg.set(task, target);
        let gen = self.jobs[job_idx].placement_gen[task];

        // ADFG dispatch message (tiny) to the target worker.
        let delta = self.cfg.cost.delta_net_us;
        let extra = self.net_extra(on_worker, target);
        let enq_at = if target == on_worker { self.now } else { self.now + delta + extra };
        self.push_event(enq_at, Event::TaskEnqueue { w: target, job_idx, task });

        // Ship every not-yet-sent input to the target.
        let dfg_idx = self.jobs[job_idx].job.kind.index();
        if self.dfgs[dfg_idx].preds[task].is_empty() {
            let td = self.cfg.cost.td_input(pred_outputs[0].1, on_worker, target);
            let extra = self.net_extra(on_worker, target);
            self.push_event(self.now + td + extra, Event::InputArrive { job_idx, task, gen });
        } else {
            let mut preds = std::mem::take(&mut self.preds_buf);
            preds.clear();
            preds.extend_from_slice(&self.dfgs[dfg_idx].preds[task]);
            for &p in &preds {
                let slot =
                    self.dfgs[dfg_idx].succs[p].iter().position(|&s| s == task).unwrap();
                let edge = self.succ_off[dfg_idx][p] + slot;
                if self.jobs[job_idx].sent[edge] {
                    continue;
                }
                self.jobs[job_idx].sent[edge] = true;
                let src = self.jobs[job_idx].output_worker[p].unwrap();
                let bytes = self.dfgs[dfg_idx].vertices[p].output_bytes;
                let td = self.cfg.cost.td_input(bytes, src, target);
                let extra = self.net_extra(src, target);
                self.push_event(self.now + td + extra, Event::InputArrive { job_idx, task, gen });
            }
            self.preds_buf = preds;
        }
        self.pred_buf = pred_outputs;
    }

    fn handle_job_arrival(&mut self, job_idx: usize) {
        // The client sends the request to an arbitrary ("ingress") worker.
        let mut ingress =
            (hash_pair(self.jobs[job_idx].job.id, INGRESS_SALT) % self.cfg.n_workers as u64)
                as WorkerId;
        if self.crashed[ingress] {
            // Connection refused is immediate: the client walks the ring
            // until a live worker accepts, or gives up on the job.
            match self.first_alive(ingress) {
                Some(w) => ingress = w,
                None => {
                    self.fail_job(job_idx);
                    return;
                }
            }
        }
        self.view_rows(ingress);
        if self.tracer.on() {
            let (id, kind) = {
                let j = &self.jobs[job_idx].job;
                (j.id, j.kind)
            };
            self.tracer.record(TraceEvent::JobArrive { job: id, kind, t: self.now });
            // Sample how stale the SST view feeding this plan was (§5.2).
            for w in 0..self.cfg.n_workers {
                let (load, cache) = self.sst.staleness_of(w, self.now);
                self.tracer.record(TraceEvent::SstStaleness {
                    worker: w as u16,
                    load_staleness_us: load,
                    cache_staleness_us: cache,
                    t: self.now,
                });
            }
        }
        let mut probe =
            if self.tracer.on() { DecisionProbe::on() } else { DecisionProbe::off() };
        let adfg = {
            let js = &self.jobs[job_idx];
            let dfg = &self.dfgs[js.job.kind.index()];
            let view = ClusterView {
                now: self.now,
                self_worker: ingress,
                rows: &self.rows_scratch,
                cost: &self.cfg.cost,
                speed: &self.speed,
                scratch: &self.plan_scratch,
            };
            // Planning phase: the initial ADFG (§4.2).
            self.scheduler.plan_probed(&js.job, dfg, &view, &mut probe)
        };
        if probe.is_active() {
            let job = self.jobs[job_idx].job.id;
            for (task, candidates) in probe.take_records() {
                let chosen = adfg.get(task).unwrap_or(ingress);
                self.tracer.record(TraceEvent::Decision {
                    job,
                    task: task as u16,
                    phase: SchedPhase::Plan,
                    decider: ingress as u16,
                    chosen: chosen as u16,
                    candidates,
                    t: self.now,
                });
            }
        }
        self.jobs[job_idx].adfg = adfg;
        // The entry task is dispatchable immediately.
        let entry = self.dfgs[self.jobs[job_idx].job.kind.index()].entry;
        self.assign_and_dispatch(job_idx, entry, ingress);
    }

    fn handle_exec_done(&mut self, w: WorkerId, job_idx: usize, task: TaskId) {
        if self.crashed[w] {
            // The worker died mid-execution; the task never finished and
            // will be re-placed when a peer detects the failure.
            return;
        }
        let finished = self.workers[w].finish_task(self.now);
        self.retire_task(w, job_idx, task, finished.runtime_us);
        self.try_dispatch(w);
    }

    /// Everything that happens when a task's execution completes on `w`,
    /// after the worker state is released: trace, profile feedback, output
    /// registration, job completion, and feeding successors. Shared between
    /// the solo `ExecDone` path and per-member batch retirement.
    fn retire_task(&mut self, w: WorkerId, job_idx: usize, task: TaskId, runtime_us: Micros) {
        if self.tracer.on() {
            self.tracer.record(TraceEvent::ExecEnd {
                job: self.jobs[job_idx].job.id,
                task: task as u16,
                worker: w as u16,
                t: self.now,
            });
        }
        let dfg_idx = self.jobs[job_idx].job.kind.index();
        // Online profile refinement (§3.1): feed the observed runtime back
        // so R(t, ·) estimates converge even when the static profile lies.
        // Batch members feed their *solo* sampled runtime: profiles model
        // R(t, w), not the coalesced batch residency.
        if let Some(repo) = &mut self.profiles {
            let kind = self.jobs[job_idx].job.kind;
            // De-bias by worker speed: profiles store reference runtimes.
            let observed = (runtime_us as f64 / self.speed[w].max(1e-9)) as Micros;
            repo.observe(kind, task, observed);
            self.dfgs[dfg_idx].vertices[task].mean_runtime_us = repo.runtime(kind, task);
        }
        let exit = self.dfgs[dfg_idx].exit;
        // Marks the task done: done(t) ⇔ output_worker[t].is_some().
        self.jobs[job_idx].output_worker[task] = Some(w);

        if task == exit && !self.jobs[job_idx].completed {
            self.jobs[job_idx].completed = true;
            self.completed_jobs += 1;
            let js = &self.jobs[job_idx];
            let outcome = if js.disrupted {
                JobOutcome::Degraded
            } else {
                JobOutcome::Completed
            };
            self.records.push(JobRecord {
                kind: js.job.kind,
                arrival_us: js.job.arrival_us,
                completion_us: self.now,
                lower_bound_us: self.dfgs[dfg_idx].lower_bound_us,
                outcome,
            });
            if self.tracer.on() {
                self.tracer.record(TraceEvent::JobComplete {
                    job: js.job.id,
                    kind: js.job.kind,
                    latency_us: self.now - js.job.arrival_us,
                    t: self.now,
                });
                if outcome == JobOutcome::Degraded {
                    self.tracer.record(TraceEvent::JobDegraded {
                        job: js.job.id,
                        kind: js.job.kind,
                        t: self.now,
                    });
                }
            }
        }

        // Successor list into a reused buffer (assign_and_dispatch below
        // re-borrows self, so we can't hold a borrow of the DFG here).
        let mut succs = std::mem::take(&mut self.succs_buf);
        succs.clear();
        succs.extend_from_slice(&self.dfgs[dfg_idx].succs[task]);
        for (slot, &s) in succs.iter().enumerate() {
            self.jobs[job_idx].remaining_preds[s] -= 1;
            if self.jobs[job_idx].remaining_preds[s] == 0 {
                // Last predecessor done: (re-)assign and dispatch.
                self.assign_and_dispatch(job_idx, s, w);
            } else if self.dfgs[dfg_idx].is_join(s) {
                // Join with a pre-coordinated placement: ship this output
                // early (the planning-phase benefit, §3.2). Join placements
                // are never dynamically adjusted, so this is safe.
                if let Some(target) = self.jobs[job_idx].adfg.get(s) {
                    let edge = self.succ_off[dfg_idx][task] + slot;
                    if !self.jobs[job_idx].sent[edge] {
                        self.jobs[job_idx].sent[edge] = true;
                        let gen = self.jobs[job_idx].placement_gen[s];
                        let bytes = self.dfgs[dfg_idx].vertices[task].output_bytes;
                        let td = self.cfg.cost.td_input(bytes, w, target);
                        let extra = self.net_extra(w, target);
                        self.push_event(
                            self.now + td + extra,
                            Event::InputArrive { job_idx, task: s, gen },
                        );
                    }
                }
            }
        }
        self.succs_buf = succs;
    }

    /// A batch finished on `w`: retire every member (in start order) and
    /// feed each job's successors, then look for the next dispatch.
    fn handle_batch_done(&mut self, w: WorkerId) {
        if self.crashed[w] {
            // The batch died with the worker; members are recovered by
            // the queue drain at detection time.
            return;
        }
        let mut done = std::mem::take(&mut self.done_buf);
        done.clear();
        let model = self.workers[w].running_batch()[0].model.expect("batch without model");
        self.workers[w].finish_batch(self.now, &mut done);
        if self.tracer.on() {
            self.tracer.record(TraceEvent::BatchExecuted {
                worker: w as u16,
                model,
                size: done.len() as u16,
                t: self.now,
            });
        }
        for k in 0..done.len() {
            let (job_idx, task, runtime_us) = (done[k].job_idx, done[k].task, done[k].runtime_us);
            self.retire_task(w, job_idx, task, runtime_us);
        }
        self.done_buf = done;
        self.try_dispatch(w);
    }

    fn try_dispatch(&mut self, w: WorkerId) {
        self.dispatch(w, false);
    }

    /// The Task Dispatcher loop (§3.2): trigger at most one model fetch
    /// (earliest input-ready task whose model is absent; PCIe is serial),
    /// then start the first runnable task if the GPU is idle. Tasks whose
    /// inputs or models aren't ready are left in place and the scan
    /// continues — fetch thus overlaps execution of later tasks.
    ///
    /// With batching enabled, the first runnable modeled task becomes a
    /// batch *leader*: consecutive same-model runnable queue-mates join it
    /// up to `batch_max`. A partial batch holds the GPU idle for at most
    /// `batch_window_us` (the `BatchWindow` event re-enters here with
    /// `force_start`); a full batch, a model-less leader, or an expired
    /// window starts immediately.
    fn dispatch(&mut self, w: WorkerId, force_start: bool) {
        if self.crashed[w] {
            return;
        }
        let now = self.now;
        let mut fetch: Option<(usize, ModelId)> = None;
        let mut start: Option<(usize, usize, TaskId, Micros, bool, Option<ModelId>)> = None;
        // Queue-lookahead buffer, reused across all scans of a run. Filled
        // lazily — most dispatch scans trigger no fetch — and read again by
        // the fetch execution below: the queue doesn't change in between,
        // so one fill serves both the decision and its execution.
        let mut lookahead = std::mem::take(&mut self.lookahead_buf);
        lookahead.clear();
        {
            let jobs = &self.jobs;
            let dfgs = &self.dfgs;
            let worker = &self.workers[w];
            let can_fetch = worker.fetching().is_none();
            let can_start = worker.running().is_none();
            let queue = worker.queue();
            let mut la_filled = false;
            for (i, qt) in queue.iter().enumerate() {
                let js = &jobs[qt.job_idx];
                let dfg = &dfgs[js.job.kind.index()];
                if js.done(qt.task) {
                    continue;
                }
                if js.inputs_arrived[qt.task] < js.needed_inputs(dfg, qt.task) {
                    continue;
                }
                match qt.model {
                    Some(m) if !worker.gpu.contains(m) => {
                        if can_fetch && fetch.is_none() {
                            // Eviction decision sees the models queued from
                            // here onward (§5.3.2 queue-lookahead).
                            if !la_filled {
                                la_filled = true;
                                worker.queue_models_into(&mut lookahead);
                            }
                            if worker.gpu.plan_eviction(model_bytes(m), &lookahead).is_some() {
                                fetch = Some((i, m));
                            }
                        }
                        // Not runnable; dispatcher proceeds to next task.
                    }
                    model => {
                        if can_start && start.is_none() {
                            let end = now + qt.runtime_us;
                            start =
                                Some((i, qt.job_idx, qt.task, end, qt.caused_fetch, model));
                        }
                    }
                }
                if start.is_some() && (fetch.is_some() || !can_fetch) {
                    break;
                }
            }
        }

        if let Some((i, m)) = fetch {
            // Re-plan eviction with mutable access and execute it; the
            // lookahead buffer is still the one the decision saw.
            let victims = self.workers[w]
                .gpu
                .plan_eviction(model_bytes(m), &lookahead)
                .expect("eviction plan vanished");
            for v in victims {
                self.workers[w].gpu.evict(v, now);
            }
            self.workers[w].gpu.record_miss(m, now);
            self.workers[w].mark_caused_fetch(i);
            self.workers[w].begin_fetch(m);
            if self.tracer.on() {
                self.tracer.record(TraceEvent::FetchStart { worker: w as u16, model: m, t: now });
            }
            let td = self.cfg.cost.td_model(model_bytes(m));
            self.push_event(now + td, Event::FetchDone { w, model: m });
        }
        self.lookahead_buf = lookahead;

        if let Some((i, job_idx, task, end, caused_fetch, model)) = start {
            let batch = self.cfg.cost.batch;
            if batch.enabled() {
                if let Some(m) = model {
                    self.start_coalesced(w, i, m, force_start);
                    return;
                }
                // Model-less vertices never batch: start solo immediately,
                // but complete through the batch path so the worker's
                // running state stays uniform while batching is on.
                self.workers[w].start_batch(&[i], now, end);
                if self.tracer.on() {
                    self.tracer.record(TraceEvent::ExecStart {
                        job: self.jobs[job_idx].job.id,
                        task: task as u16,
                        worker: w as u16,
                        t: now,
                    });
                }
                self.push_event(end, Event::BatchDone { w });
                return;
            }
            if let (Some(m), false) = (model, caused_fetch) {
                self.workers[w].gpu.record_hit(m, now);
            }
            // The fetch marking above didn't reorder the queue, so index i
            // is still valid (eviction doesn't touch the queue).
            debug_assert_eq!(self.workers[w].queue()[i].task, task);
            self.workers[w].start_task(i, now, end);
            if self.tracer.on() {
                self.tracer.record(TraceEvent::ExecStart {
                    job: self.jobs[job_idx].job.id,
                    task: task as u16,
                    worker: w as u16,
                    t: now,
                });
            }
            self.push_event(end, Event::ExecDone { w, job_idx, task });
        }
    }

    /// Batching-enabled start: coalesce leader `queue[i]` (model `m`) with
    /// consecutive same-model runnable followers, or arm the hold window if
    /// the batch is still short of `batch_max`.
    fn start_coalesced(&mut self, w: WorkerId, i: usize, m: ModelId, force_start: bool) {
        let now = self.now;
        let batch = self.cfg.cost.batch;
        let mut members = std::mem::take(&mut self.members_buf);
        members.clear();
        members.push(i);
        {
            let worker = &self.workers[w];
            let queue = worker.queue();
            for (j, qt) in queue.iter().enumerate().skip(i + 1) {
                if members.len() >= batch.batch_max {
                    break;
                }
                let js = &self.jobs[qt.job_idx];
                if js.done(qt.task) {
                    continue;
                }
                // "Consecutive": the run ends at the first live entry that
                // is a different model or not yet input-ready.
                if qt.model != Some(m) {
                    break;
                }
                let dfg = &self.dfgs[js.job.kind.index()];
                if js.inputs_arrived[qt.task] < js.needed_inputs(dfg, qt.task) {
                    break;
                }
                members.push(j);
            }
        }

        let full = members.len() >= batch.batch_max;
        if !full && batch.window_us > 0 && !force_start {
            // Hold for queue-mates; one timer per hold (stale timers are
            // detected by deadline mismatch and ignored).
            if self.workers[w].hold_until().is_none() {
                let deadline = now + batch.window_us;
                self.workers[w].set_hold(deadline);
                self.push_event(deadline, Event::BatchWindow { w, deadline });
            }
            self.members_buf = members;
            return;
        }

        // Per-member cache accounting, as if each had started solo.
        let (mut max_us, mut sum_us): (Micros, Micros) = (0, 0);
        for &j in &members {
            let (rt, caused_fetch) = {
                let qt = &self.workers[w].queue()[j];
                (qt.runtime_us, qt.caused_fetch)
            };
            max_us = max_us.max(rt);
            sum_us += rt;
            if !caused_fetch {
                self.workers[w].gpu.record_hit(m, now);
            }
        }
        let alpha = batch.alpha(crate::dfg::models::batch_alpha(m));
        let end = now + batch.batch_runtime_us(max_us, sum_us, alpha);
        self.workers[w].start_batch(&members, now, end);
        if self.tracer.on() {
            self.tracer.record(TraceEvent::BatchFormed {
                worker: w as u16,
                model: m,
                size: members.len() as u16,
                t: now,
            });
            for qt in self.workers[w].running_batch() {
                self.tracer.record(TraceEvent::ExecStart {
                    job: self.jobs[qt.job_idx].job.id,
                    task: qt.task as u16,
                    worker: w as u16,
                    t: now,
                });
            }
        }
        self.push_event(end, Event::BatchDone { w });
        self.members_buf = members;
    }

    fn handle_enqueue(&mut self, w: WorkerId, job_idx: usize, task: TaskId) {
        if self.crashed[w] && self.sst.rows()[w].poisoned() {
            // Late ADFG message to a worker already declared dead — the
            // sender decided before the poison reached it. Recover right
            // away instead of parking the task on a corpse. (A message to
            // a crashed-but-undetected worker enqueues normally and is
            // recovered by the queue drain at detection time.)
            match self.first_alive(w) {
                Some(d) => self.re_place(job_idx, task, d),
                None => self.fail_job(job_idx),
            }
            return;
        }
        let (base, model) = {
            let k = self.jobs[job_idx].job.kind.index();
            // Actual work follows the ground truth, not the profile claim.
            (
                (self.true_runtimes[k][task] * self.speed[w]).max(1.0),
                self.dfgs[k].vertices[task].model,
            )
        };
        let mut runtime = self.workers[w].sample_runtime(base, self.cfg.runtime_jitter);
        // Straggler fault injection: some tasks unpredictably blow through
        // their profile (the §3.2 motivation for dynamic adjustment).
        if self.cfg.straggler_prob > 0.0
            && self.workers[w].roll_straggler(self.cfg.straggler_prob)
        {
            runtime = (runtime as f64 * self.cfg.straggler_factor) as Micros;
        }
        // Transient slowdown fault: a degraded-but-alive worker. Pure
        // window lookup, no RNG draw — inert when the plan has none.
        if let Some(f) = self.fault_plan.slowdown_factor(w, self.now) {
            runtime = (runtime as f64 * f) as Micros;
        }
        self.workers[w].enqueue(QTask {
            job_idx,
            task,
            model,
            runtime_us: runtime,
            caused_fetch: false,
        });
        if self.tracer.on() {
            self.tracer.record(TraceEvent::TaskEnqueue {
                job: self.jobs[job_idx].job.id,
                task: task as u16,
                worker: w as u16,
                t: self.now,
            });
        }
        self.try_dispatch(w);
    }

    /// PCIe fetch completion, with transient-failure injection: a fetch
    /// may fail and be retried with exponential backoff, and the *final*
    /// allowed attempt always succeeds so retries terminate. Inert (no
    /// RNG draw, no branch taken) when `fetch_fail_prob == 0`.
    fn handle_fetch_done(&mut self, w: WorkerId, model: ModelId) {
        if self.crashed[w] {
            return;
        }
        if self.cfg.fault.fetch_fail_prob > 0.0 {
            let slot = w * N_MODELS + model as usize;
            let attempt = self.fetch_attempts[slot];
            let last = attempt + 1 >= self.cfg.fault.retry.max_attempts;
            if !last && self.fault_rng.f64() < self.cfg.fault.fetch_fail_prob {
                self.fetch_attempts[slot] = attempt + 1;
                self.fault_stats.task_retries += 1;
                if self.tracer.on() {
                    self.tracer.record(TraceEvent::TaskRetried {
                        worker: w as u16,
                        model,
                        attempt: attempt as u16,
                        t: self.now,
                    });
                }
                // Back off, then redo the transfer; `fetching` stays set,
                // so the PCIe bus remains (correctly) occupied throughout.
                let at = self.now
                    + self.cfg.fault.retry.backoff_us(attempt)
                    + self.cfg.cost.td_model(model_bytes(model));
                self.push_event(at, Event::FetchDone { w, model });
                return;
            }
            self.fetch_attempts[slot] = 0;
        }
        self.workers[w].finish_fetch(model, self.now);
        if self.tracer.on() {
            self.tracer.record(TraceEvent::FetchEnd { worker: w as u16, model, t: self.now });
        }
        self.try_dispatch(w);
    }

    /// Terminal failure: the job can no longer make progress (no live
    /// worker to run or re-place its tasks). Records a
    /// [`JobOutcome::Failed`] row so the job still reaches a terminal
    /// outcome and the event loop's completion accounting terminates.
    fn fail_job(&mut self, job_idx: usize) {
        if self.jobs[job_idx].completed {
            return;
        }
        self.jobs[job_idx].completed = true;
        self.completed_jobs += 1;
        self.fault_stats.jobs_failed += 1;
        let js = &self.jobs[job_idx];
        self.records.push(JobRecord {
            kind: js.job.kind,
            arrival_us: js.job.arrival_us,
            completion_us: self.now,
            lower_bound_us: self.dfgs[js.job.kind.index()].lower_bound_us,
            outcome: JobOutcome::Failed,
        });
    }

    /// Void every in-flight input transfer for (job, task) and forget the
    /// per-edge sent flags, so the next `assign_and_dispatch` re-requests
    /// each predecessor output from its (durable) holder. The generation
    /// bump makes stale `InputArrive` events self-identify.
    fn invalidate_inputs(&mut self, job_idx: usize, task: TaskId) {
        self.jobs[job_idx].placement_gen[task] += 1;
        self.jobs[job_idx].inputs_arrived[task] = 0;
        let dfg_idx = self.jobs[job_idx].job.kind.index();
        let n_preds = self.dfgs[dfg_idx].preds[task].len();
        for pi in 0..n_preds {
            let p = self.dfgs[dfg_idx].preds[task][pi];
            let slot =
                self.dfgs[dfg_idx].succs[p].iter().position(|&s| s == task).expect("edge");
            let edge = self.succ_off[dfg_idx][p] + slot;
            self.jobs[job_idx].sent[edge] = false;
        }
    }

    /// Re-place one task orphaned by a worker failure: invalidate its old
    /// transfers and run it back through Algorithm 2 on `decider` (the
    /// detecting worker). The dead row is poisoned, so every scheduler
    /// steers the task elsewhere; re-placement accounting happens inside
    /// `assign_and_dispatch`, shared with the pinned-join rescue path.
    fn re_place(&mut self, job_idx: usize, task: TaskId, decider: WorkerId) {
        if self.jobs[job_idx].completed || self.jobs[job_idx].done(task) {
            return;
        }
        if self.alive_workers == 0 {
            self.fail_job(job_idx);
            return;
        }
        self.invalidate_inputs(job_idx, task);
        self.assign_and_dispatch(job_idx, task, decider);
    }

    /// `detector` noticed `p` went silent: poison the SST row (all four
    /// schedulers mask it from now on), drain the dead worker's queued and
    /// running tasks, and re-place each orphan. Tasks merely *planned*
    /// onto `p` (pinned joins with early-shipped inputs) get their
    /// transfers invalidated here and are re-placed at assign time.
    fn on_worker_failed(&mut self, p: WorkerId, detector: WorkerId) {
        self.sst.poison(p, self.now);
        self.fault_stats.workers_failed += 1;
        if self.tracer.on() {
            self.tracer.record(TraceEvent::WorkerFailed {
                worker: p as u16,
                detector: detector as u16,
                t: self.now,
            });
        }
        // Tasks planned-but-not-yet-dispatched onto p: their early-shipped
        // inputs sit on a dead worker; void them so the forced assign-time
        // re-placement re-requests everything.
        for job_idx in 0..self.jobs.len() {
            if self.jobs[job_idx].completed {
                continue;
            }
            let n = self.dfgs[self.jobs[job_idx].job.kind.index()].len();
            for task in 0..n {
                if self.jobs[job_idx].adfg.get(task) == Some(p)
                    && !self.jobs[job_idx].done(task)
                    && self.jobs[job_idx].remaining_preds[task] > 0
                {
                    self.invalidate_inputs(job_idx, task);
                }
            }
        }
        let mut orphans = std::mem::take(&mut self.orphan_buf);
        orphans.clear();
        let crash_t = self.crash_at_us[p];
        self.workers[p].crash(crash_t, &mut orphans);
        // lint: hot-path
        for k in 0..orphans.len() {
            let (job_idx, task) = (orphans[k].job_idx, orphans[k].task);
            self.re_place(job_idx, task, detector);
        }
        // lint: end-hot-path
        self.orphan_buf = orphans;
    }

    /// Failure detection, run by `detector` on its own SST push tick: any
    /// crashed peer whose row has gone stale past the heartbeat timeout is
    /// declared dead. Rate-limited pushes double as heartbeats (§5.2), so
    /// detection latency ≈ heartbeat timeout + one push interval.
    fn detect_failures(&mut self, detector: WorkerId) {
        let timeout = self.cfg.fault.heartbeat_timeout_us;
        for p in 0..self.cfg.n_workers {
            if p == detector || !self.crashed[p] {
                continue;
            }
            if self.sst.is_stale(p, self.now, timeout) {
                self.on_worker_failed(p, detector);
            }
        }
    }

    /// Run the full workload to completion; returns metrics. Takes the
    /// jobs by reference so sweeps (and benches) can share one workload
    /// across many runs without cloning it per run.
    pub fn run(&mut self, jobs: &[Job]) -> SimReport {
        self.jobs.reserve(jobs.len());
        self.queue.reserve(jobs.len() + 2 * self.cfg.n_workers);
        for job in jobs {
            let js = JobState::new(job.clone(), &self.dfgs[job.kind.index()]);
            let idx = self.jobs.len();
            self.jobs.push(js);
            self.push_event(job.arrival_us, Event::JobArrival { job_idx: idx });
        }
        for w in 0..self.cfg.n_workers {
            self.push_event(0, Event::PushLoad { w });
            self.push_event(0, Event::PushCache { w });
        }
        if self.fault_plan.has_crashes() {
            for w in 0..self.cfg.n_workers {
                if let Some(t) = self.fault_plan.crash_at[w] {
                    self.push_event(t, Event::WorkerCrash { w });
                }
            }
        }

        const MAX_EVENTS: u64 = 500_000_000;
        while let Some((at, ev)) = self.queue.pop() {
            debug_assert!(at >= self.now, "time went backwards");
            self.now = at;
            self.events_processed += 1;
            assert!(
                self.events_processed <= MAX_EVENTS,
                "simulation exceeded {MAX_EVENTS} events — livelock?"
            );
            match ev {
                Event::JobArrival { job_idx } => self.handle_job_arrival(job_idx),
                Event::TaskEnqueue { w, job_idx, task } => self.handle_enqueue(w, job_idx, task),
                Event::InputArrive { job_idx, task, gen } => {
                    // A stale generation means the task was re-placed
                    // while this transfer was in flight: drop it.
                    if gen == self.jobs[job_idx].placement_gen[task] {
                        self.jobs[job_idx].inputs_arrived[task] += 1;
                        if let Some(w) = self.jobs[job_idx].adfg.get(task) {
                            self.try_dispatch(w);
                        }
                    }
                }
                Event::FetchDone { w, model } => self.handle_fetch_done(w, model),
                Event::ExecDone { w, job_idx, task } => self.handle_exec_done(w, job_idx, task),
                Event::BatchWindow { w, deadline } => {
                    // Stale once the hold it armed is gone (batch started).
                    if self.workers[w].hold_until() == Some(deadline) {
                        self.workers[w].clear_hold();
                        self.dispatch(w, true);
                    }
                }
                Event::BatchDone { w } => self.handle_batch_done(w),
                Event::PushLoad { w } => {
                    // A crashed worker falls silent: no push, no re-arm.
                    // The resulting SST staleness IS the failure signal.
                    if !self.crashed[w] {
                        let ft = self.workers[w].ft_estimate(self.now, &self.cfg.cost.batch);
                        self.sst.push_load(w, ft, self.now);
                        if self.completed_jobs < self.jobs.len() {
                            let at = self.now + self.cfg.push.load_interval_us;
                            self.push_event(at, Event::PushLoad { w });
                        }
                        if self.fault_plan.has_crashes() {
                            self.detect_failures(w);
                        }
                    }
                }
                Event::PushCache { w } => {
                    if !self.crashed[w] {
                        let (bitmap, free) = {
                            let g = &self.workers[w].gpu;
                            (g.bitmap(), g.free_bytes())
                        };
                        self.sst.push_cache(w, bitmap, free, self.now);
                        if self.completed_jobs < self.jobs.len() {
                            let at = self.now + self.cfg.push.cache_interval_us;
                            self.push_event(at, Event::PushCache { w });
                        }
                    }
                }
                Event::WorkerCrash { w } => {
                    self.crashed[w] = true;
                    self.crash_at_us[w] = self.now;
                    self.alive_workers -= 1;
                }
            }
        }

        // Backstop: if every worker died, surviving events drain and jobs
        // that never got a detector are still owed a terminal outcome.
        if self.fault_plan.has_crashes() {
            for job_idx in 0..self.jobs.len() {
                if !self.jobs[job_idx].completed {
                    self.fail_job(job_idx);
                }
            }
        }

        // Merge each worker's cache event log into the trace. These carry
        // their original timestamps; Chrome/Perfetto don't require the
        // event stream to be globally time-sorted.
        if self.tracer.on() {
            for w in 0..self.workers.len() {
                for ev in self.workers[w].gpu.drain_log() {
                    let worker = w as u16;
                    let (model, free_bytes, t) = (ev.model, ev.free_bytes, ev.at_us);
                    self.tracer.record(match ev.kind {
                        CacheEventKind::Hit => {
                            TraceEvent::CacheHit { worker, model, free_bytes, t }
                        }
                        CacheEventKind::Miss => {
                            TraceEvent::CacheMiss { worker, model, free_bytes, t }
                        }
                        CacheEventKind::Insert => {
                            TraceEvent::CacheInsert { worker, model, free_bytes, t }
                        }
                        CacheEventKind::Evict => {
                            TraceEvent::CacheEvict { worker, model, free_bytes, t }
                        }
                    });
                }
            }
        }

        let span = self.now;
        let workers: Vec<WorkerMetrics> =
            self.workers.iter_mut().map(|wk| wk.metrics(span)).collect();
        SimReport {
            metrics: MetricsSink {
                jobs: self.records.clone(),
                workers,
                span_us: span,
                incomplete: self.jobs.len() - self.completed_jobs,
                faults: self.fault_stats,
            },
            events_processed: self.events_processed,
            sim_span_us: span,
            trace: self.tracer.take(),
        }
    }

    /// Convenience: build, run, report.
    pub fn simulate(cfg: ClusterConfig, jobs: Vec<Job>) -> SimReport {
        Simulator::new(cfg).run(&jobs)
    }

    /// Borrowing variant of [`Simulator::simulate`]: sweeps and benches
    /// run one shared workload against many configs without per-run
    /// clones (the config clone is setup, not measured work).
    pub fn simulate_ref(cfg: &ClusterConfig, jobs: &[Job]) -> SimReport {
        Simulator::new(cfg.clone()).run(jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerKind;
    use crate::core::SEC;
    use crate::dfg::PipelineKind;
    use crate::workload;

    fn one_job(kind: PipelineKind) -> Vec<Job> {
        vec![Job { id: 0, kind, arrival_us: 0, input_bytes: 1000 }]
    }

    #[test]
    fn single_job_completes_near_lower_bound() {
        for kind in PipelineKind::ALL {
            let cfg = ClusterConfig::default();
            let rep = Simulator::simulate(cfg, one_job(kind));
            assert_eq!(rep.metrics.jobs.len(), 1, "{kind:?}");
            let sd = rep.metrics.jobs[0].slowdown();
            // Cold caches mean model fetches; still within a small factor.
            assert!(sd >= 0.6 && sd < 4.0, "{kind:?} slowdown={sd}");
        }
    }

    #[test]
    fn all_schedulers_complete_all_jobs() {
        let jobs = workload::poisson(1.0, 40, &[], 11);
        for kind in SchedulerKind::ALL {
            let cfg = ClusterConfig::default().with_scheduler(kind);
            let rep = Simulator::simulate(cfg, jobs.clone());
            assert_eq!(rep.metrics.jobs.len(), 40, "{kind:?}");
            assert_eq!(rep.metrics.incomplete, 0, "{kind:?}");
        }
    }

    #[test]
    fn deterministic_runs() {
        let jobs = workload::poisson(2.0, 60, &[], 5);
        let a = Simulator::simulate(ClusterConfig::default(), jobs.clone());
        let b = Simulator::simulate(ClusterConfig::default(), jobs);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.sim_span_us, b.sim_span_us);
        let la: Vec<_> = a.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        let lb: Vec<_> = b.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        assert_eq!(la, lb);
    }

    #[test]
    fn simulate_ref_matches_owned() {
        let jobs = workload::poisson(2.0, 40, &[], 5);
        let cfg = ClusterConfig::default();
        let a = Simulator::simulate(cfg.clone(), jobs.clone());
        let b = Simulator::simulate_ref(&cfg, &jobs);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.sim_span_us, b.sim_span_us);
        let la: Vec<_> = a.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        let lb: Vec<_> = b.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        assert_eq!(la, lb);
    }

    #[test]
    fn warm_cache_beats_cold() {
        // Second identical job should be faster: model already resident.
        let jobs = vec![
            Job { id: 0, kind: PipelineKind::Vpa, arrival_us: 0, input_bytes: 100 },
            Job { id: 1, kind: PipelineKind::Vpa, arrival_us: 20 * SEC, input_bytes: 100 },
        ];
        let rep = Simulator::simulate(ClusterConfig::default(), jobs);
        let l0 = rep.metrics.jobs[0].latency_us();
        let l1 = rep.metrics.jobs[1].latency_us();
        assert!(l1 < l0, "warm {l1} !< cold {l0}");
    }

    #[test]
    fn slowdown_grows_with_load() {
        let low = Simulator::simulate(
            ClusterConfig::default(),
            workload::poisson(0.5, 60, &[], 7),
        );
        let high = Simulator::simulate(
            ClusterConfig::default(),
            workload::poisson(4.0, 60, &[], 7),
        );
        assert!(
            high.metrics.mean_slowdown() > low.metrics.mean_slowdown(),
            "high {} !> low {}",
            high.metrics.mean_slowdown(),
            low.metrics.mean_slowdown()
        );
    }

    #[test]
    fn trace_is_empty_when_disabled() {
        let rep = Simulator::simulate(
            ClusterConfig::default(),
            workload::poisson(1.0, 10, &[], 3),
        );
        assert!(rep.trace.is_empty());
        assert_eq!(rep.trace.dropped, 0);
    }

    #[test]
    fn traced_run_records_spans_and_decisions() {
        let mut cfg = ClusterConfig::default();
        cfg.trace.enabled = true;
        let rep = Simulator::simulate(cfg, workload::poisson(1.0, 10, &[], 3));
        let t = &rep.trace;
        assert_eq!(rep.metrics.incomplete, 0);
        assert_eq!(
            t.count(|e| matches!(e, TraceEvent::JobComplete { .. })),
            rep.metrics.jobs.len()
        );
        // Every executed task yields a complete Enqueue→Start→End span.
        let ends = t.count(|e| matches!(e, TraceEvent::ExecEnd { .. }));
        assert_eq!(t.task_spans().len(), ends);
        assert!(ends >= rep.metrics.jobs.len());
        // Cold caches force at least one fetch, and decisions were probed.
        assert!(!t.fetch_spans().is_empty());
        assert!(t.count(|e| matches!(e, TraceEvent::Decision { .. })) > 0);
        assert!(t.count(|e| matches!(e, TraceEvent::CacheInsert { .. })) > 0);
        assert!(t.count(|e| matches!(e, TraceEvent::SstStaleness { .. })) > 0);
    }

    #[test]
    fn metrics_are_populated() {
        let rep = Simulator::simulate(
            ClusterConfig::default(),
            workload::poisson(1.0, 30, &[], 9),
        );
        let m = &rep.metrics;
        assert!(m.gpu_utilization() > 0.0);
        assert!(m.gpu_memory_utilization() > 0.0);
        assert!(m.gpu_energy_joules() > 0.0);
        assert!(m.cache_hit_rate() > 0.0);
        assert!(m.active_workers() >= 1);
        assert!(rep.events_processed > 0);
    }

    /// The same-model-heavy workload the batching sweep stresses: one
    /// pipeline kind, so queues fill with repeats of the same few models.
    fn same_model_heavy(rate: f64, n: usize, seed: u64) -> Vec<Job> {
        workload::poisson(rate, n, &[0.0, 0.0, 1.0, 0.0], seed)
    }

    #[test]
    fn batching_completes_all_jobs_all_schedulers() {
        let jobs = workload::poisson(2.0, 40, &[], 11);
        for kind in SchedulerKind::ALL {
            for batch_max in [2, 4, 8] {
                let cfg = ClusterConfig::default()
                    .with_scheduler(kind)
                    .with_batching(batch_max, 1000);
                let rep = Simulator::simulate(cfg, jobs.clone());
                assert_eq!(rep.metrics.incomplete, 0, "{kind:?} batch_max={batch_max}");
            }
        }
    }

    #[test]
    fn batching_reduces_latency_under_same_model_load() {
        let jobs = same_model_heavy(4.0, 80, 17);
        let off = Simulator::simulate(ClusterConfig::default(), jobs.clone());
        let on =
            Simulator::simulate(ClusterConfig::default().with_batching(8, 1000), jobs);
        assert!(
            on.metrics.mean_latency_s() < off.metrics.mean_latency_s(),
            "batched {} !< unbatched {}",
            on.metrics.mean_latency_s(),
            off.metrics.mean_latency_s()
        );
    }

    #[test]
    fn batch_max_one_is_bit_identical_to_default() {
        // batch_max = 1 must keep every code path on the unbatched route:
        // identical event counts, spans, and latencies bit for bit.
        let jobs = workload::poisson(2.0, 60, &[], 5);
        let base = Simulator::simulate(ClusterConfig::default(), jobs.clone());
        let mut cfg = ClusterConfig::default().with_batching(1, 777);
        cfg.cost.batch.alpha_override = Some(0.3); // irrelevant at batch_max 1
        let one = Simulator::simulate(cfg, jobs);
        assert_eq!(base.events_processed, one.events_processed);
        assert_eq!(base.sim_span_us, one.sim_span_us);
        let la: Vec<_> = base.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        let lb: Vec<_> = one.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        assert_eq!(la, lb);
    }

    #[test]
    fn traced_batch_run_emits_batch_events() {
        let mut cfg = ClusterConfig::default().with_batching(4, 1000);
        cfg.trace.enabled = true;
        let rep = Simulator::simulate(cfg, same_model_heavy(4.0, 60, 3));
        let t = &rep.trace;
        assert_eq!(rep.metrics.incomplete, 0);
        let formed = t.count(|e| matches!(e, TraceEvent::BatchFormed { .. }));
        let executed = t.count(|e| matches!(e, TraceEvent::BatchExecuted { .. }));
        assert!(formed > 0, "no batches formed under same-model-heavy load");
        // Every formed multi-member batch executes; model-less singletons
        // add BatchExecuted events without a BatchFormed.
        assert!(executed >= formed);
        // At least one real coalescing happened.
        assert!(t.events.iter().any(
            |e| matches!(e, TraceEvent::BatchFormed { size, .. } if *size >= 2)
        ));
    }

    #[test]
    fn inert_fault_knobs_do_not_perturb_the_run() {
        // Fault knobs that enable nothing (seed/timeout changes only)
        // must leave the run byte-identical: no extra events, no RNG
        // perturbation, no fault counters.
        let jobs = workload::poisson(2.0, 60, &[], 5);
        let base = Simulator::simulate(ClusterConfig::default(), jobs.clone());
        let mut cfg = ClusterConfig::default();
        cfg.fault.seed = 999;
        cfg.fault.heartbeat_timeout_us = 5 * SEC;
        let b = Simulator::simulate(cfg, jobs);
        assert_eq!(base.events_processed, b.events_processed);
        assert_eq!(base.sim_span_us, b.sim_span_us);
        let la: Vec<_> = base.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        let lb: Vec<_> = b.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        assert_eq!(la, lb);
        assert_eq!(base.metrics.faults, crate::metrics::FaultStats::default());
        assert_eq!(b.metrics.faults, crate::metrics::FaultStats::default());
    }

    #[test]
    fn worker_crash_recovers_all_jobs() {
        let jobs = workload::poisson(4.0, 80, &[], 11);
        let mut cfg = ClusterConfig::default();
        cfg.fault.crashes = vec![(2, 3 * SEC)];
        let rep = Simulator::simulate(cfg, jobs);
        // Every job reaches a terminal outcome; with survivors around,
        // none fail — disrupted ones complete Degraded.
        assert_eq!(rep.metrics.jobs.len(), 80);
        assert_eq!(rep.metrics.incomplete, 0);
        assert_eq!(rep.metrics.faults.workers_failed, 1);
        assert_eq!(rep.metrics.faults.jobs_failed, 0);
        assert!(rep.metrics.faults.tasks_re_placed > 0, "crash mid-load orphaned nothing?");
        assert!(rep.metrics.degraded_jobs() > 0);
        assert!((rep.metrics.completion_rate() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn crash_runs_are_deterministic() {
        let jobs = workload::poisson(3.0, 60, &[], 7);
        let mut cfg = ClusterConfig::default();
        cfg.fault.crash_rate = 0.3;
        cfg.fault.fetch_fail_prob = 0.2;
        cfg.fault.slowdown_rate = 0.3;
        let a = Simulator::simulate(cfg.clone(), jobs.clone());
        let b = Simulator::simulate(cfg, jobs);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.sim_span_us, b.sim_span_us);
        assert_eq!(a.metrics.faults, b.metrics.faults);
        let la: Vec<_> = a.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        let lb: Vec<_> = b.metrics.jobs.iter().map(|j| j.latency_us()).collect();
        assert_eq!(la, lb);
    }

    #[test]
    fn all_workers_dead_jobs_fail_terminally() {
        use crate::core::MS;
        let mut cfg = ClusterConfig::default();
        cfg.fault.crashes = (0..cfg.n_workers).map(|w| (w, MS)).collect();
        let rep = Simulator::simulate(cfg, workload::poisson(1.0, 10, &[], 3));
        assert_eq!(rep.metrics.jobs.len(), 10);
        assert_eq!(rep.metrics.incomplete, 0);
        assert_eq!(rep.metrics.faults.jobs_failed, 10);
        assert!(rep.metrics.completion_rate() < 1e-9);
    }

    #[test]
    fn fetch_retries_delay_but_complete() {
        let jobs = workload::poisson(1.0, 20, &[], 9);
        let mut cfg = ClusterConfig::default();
        cfg.fault.fetch_fail_prob = 0.5;
        let rep = Simulator::simulate(cfg, jobs.clone());
        assert_eq!(rep.metrics.incomplete, 0);
        assert_eq!(rep.metrics.faults.jobs_failed, 0);
        assert!(rep.metrics.faults.task_retries > 0, "cold caches fetched without failures?");
        let base = Simulator::simulate(ClusterConfig::default(), jobs);
        assert!(rep.metrics.mean_latency_s() > base.metrics.mean_latency_s());
    }

    #[test]
    fn transient_slowdown_inflates_latency() {
        let jobs = workload::poisson(2.0, 60, &[], 5);
        let base = Simulator::simulate(ClusterConfig::default(), jobs.clone());
        let mut cfg = ClusterConfig::default();
        cfg.fault.slowdown_rate = 1.0;
        cfg.fault.slowdown_factor = 8.0;
        cfg.fault.slowdown_us = 30 * SEC;
        let slow = Simulator::simulate(cfg, jobs);
        assert_eq!(slow.metrics.incomplete, 0);
        assert!(slow.metrics.mean_latency_s() > base.metrics.mean_latency_s());
        // No crashes involved: nothing failed, nothing re-placed.
        assert_eq!(slow.metrics.faults, crate::metrics::FaultStats::default());
    }

    #[test]
    fn net_faults_delay_remote_messages_deterministically() {
        use crate::core::MS;
        let jobs = workload::poisson(2.0, 40, &[], 13);
        let mut cfg = ClusterConfig::default();
        cfg.fault.delay_prob = 0.5;
        cfg.fault.delay_us = 50 * MS;
        cfg.fault.drop_prob = 0.2;
        let a = Simulator::simulate(cfg.clone(), jobs.clone());
        let b = Simulator::simulate(cfg, jobs.clone());
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.sim_span_us, b.sim_span_us);
        assert_eq!(a.metrics.incomplete, 0);
        let base = Simulator::simulate(ClusterConfig::default(), jobs);
        assert!(a.metrics.mean_latency_s() > base.metrics.mean_latency_s());
    }

    #[test]
    fn traced_crash_run_emits_fault_events() {
        let mut cfg = ClusterConfig::default();
        cfg.trace.enabled = true;
        cfg.fault.crashes = vec![(1, 3 * SEC)];
        let rep = Simulator::simulate(cfg, workload::poisson(4.0, 80, &[], 11));
        let t = &rep.trace;
        assert_eq!(rep.metrics.incomplete, 0);
        assert_eq!(t.count(|e| matches!(e, TraceEvent::WorkerFailed { .. })), 1);
        assert!(t.count(|e| matches!(e, TraceEvent::TaskRePlaced { .. })) > 0);
        assert_eq!(
            t.count(|e| matches!(e, TraceEvent::JobDegraded { .. })),
            rep.metrics.degraded_jobs()
        );
    }

    #[test]
    fn lone_task_starts_after_window_not_before() {
        use crate::core::MS;
        // A single VPA job: its tasks have no queue-mates, so each modeled
        // task waits out the hold window; the job still completes.
        let window = 5 * MS;
        let jobs = one_job(PipelineKind::Vpa);
        let off = Simulator::simulate(ClusterConfig::default(), jobs.clone());
        let on = Simulator::simulate(
            ClusterConfig::default().with_batching(8, window),
            jobs,
        );
        let l_off = off.metrics.jobs[0].latency_us();
        let l_on = on.metrics.jobs[0].latency_us();
        assert!(l_on > l_off, "hold window should delay a lone job");
        // Bounded: at most one window per task of the pipeline.
        assert!(l_on <= l_off + 8 * window, "l_on={l_on} l_off={l_off}");
    }
}
