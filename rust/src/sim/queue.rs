//! Slab-backed pending-event arena + index min-heap for the simulator.
//!
//! The old queue was `BinaryHeap<Reverse<(Micros, u64, Event)>>`: every
//! push moved the whole `(time, seq, Event)` tuple, and every sift moved
//! it again — the enum payload rode along through every heap swap. Here
//! payloads park once in a slab slot (recycled through a free list, so a
//! steady-state run stops allocating) and the heap orders 24-byte
//! `(at, seq, slot)` index entries only.
//!
//! Ordering is *exactly* the old queue's: strictly `(at, seq)` with `seq`
//! assigned per push, monotonically increasing. Since `seq` is unique the
//! payload never participates in comparisons — the old tuple heap never
//! reached its third field either — so event order, and therefore every
//! simulation result, is bit-identical (locked by the reference-model
//! property test below).

use crate::core::Micros;

#[derive(Debug, Clone, Copy)]
struct Entry {
    at: Micros,
    seq: u64,
    slot: u32,
}

impl Entry {
    #[inline]
    fn key(&self) -> (Micros, u64) {
        (self.at, self.seq)
    }
}

#[derive(Debug)]
pub struct EventQueue<T> {
    /// Payload arena; `None` slots are free and listed in `free`.
    slab: Vec<Option<T>>,
    free: Vec<u32>,
    /// Manual binary min-heap over `(at, seq)`.
    heap: Vec<Entry>,
    /// Deterministic tiebreaker: creation order among simultaneous events.
    seq: u64,
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { slab: Vec::new(), free: Vec::new(), heap: Vec::new(), seq: 0 }
    }

    pub fn reserve(&mut self, additional: usize) {
        self.slab.reserve(additional);
        self.heap.reserve(additional);
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    // lint: hot-path
    // Per-event operations: every simulated event passes through push/pop,
    // so this region must stay allocation-free in steady state (the slab
    // free list recycles slots; `Vec::push` growth is amortized-zero).
    pub fn push(&mut self, at: Micros, ev: T) {
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s as usize] = Some(ev);
                s
            }
            None => {
                self.slab.push(Some(ev));
                (self.slab.len() - 1) as u32
            }
        };
        self.seq += 1;
        self.heap.push(Entry { at, seq: self.seq, slot });
        self.sift_up(self.heap.len() - 1);
    }

    /// Earliest `(at, seq)` event; its slab slot returns to the free list.
    pub fn pop(&mut self) -> Option<(Micros, T)> {
        if self.heap.is_empty() {
            return None;
        }
        let top = self.heap.swap_remove(0);
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        let ev = self.slab[top.slot as usize].take().expect("heap entry points at live slot");
        self.free.push(top.slot);
        Some((top.at, ev))
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].key() < self.heap[parent].key() {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let mut min = i;
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            if l < n && self.heap[l].key() < self.heap[min].key() {
                min = l;
            }
            if r < n && self.heap[r].key() < self.heap[min].key() {
                min = r;
            }
            if min == i {
                break;
            }
            self.heap.swap(i, min);
            i = min;
        }
    }
    // lint: end-hot-path

    /// Arena footprint (live + free slots) — exposed for the reuse test.
    #[cfg(test)]
    fn slab_len(&self) -> usize {
        self.slab.len()
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        EventQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[test]
    fn pops_in_time_order_with_fifo_ties() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a1");
        q.push(10, "a2");
        q.push(20, "b");
        assert_eq!(q.len(), 4);
        assert_eq!(q.pop(), Some((10, "a1")));
        assert_eq!(q.pop(), Some((10, "a2")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn slots_are_recycled() {
        let mut q = EventQueue::new();
        for round in 0..100u64 {
            q.push(round, round);
            q.push(round, round + 1);
            q.pop();
            q.pop();
        }
        // Peak occupancy was 2, so the arena never grew past it.
        assert!(q.slab_len() <= 2, "slab grew to {}", q.slab_len());
    }

    /// The determinism lock for the arena rewrite: against the exact
    /// structure the simulator used before (`BinaryHeap<Reverse<(at, seq,
    /// payload)>>`), an arbitrary interleaving of pushes and pops yields an
    /// identical event sequence.
    #[test]
    fn matches_old_binary_heap_model() {
        check("event-queue-vs-binaryheap", 0xE5E7, |rng| {
            let mut q: EventQueue<u64> = EventQueue::new();
            let mut model: BinaryHeap<Reverse<(Micros, u64, u64)>> = BinaryHeap::new();
            let mut seq = 0u64;
            let mut now = 0u64;
            for step in 0..400u64 {
                if rng.below(10) < 6 || model.is_empty() {
                    // Small time range on purpose: forces (at, seq) ties.
                    let at = now + rng.below(8);
                    seq += 1;
                    q.push(at, step);
                    model.push(Reverse((at, seq, step)));
                } else {
                    let got = q.pop();
                    let want = model.pop().map(|Reverse((at, _, p))| (at, p));
                    if got != want {
                        return Err(format!("pop mismatch: got {got:?} want {want:?}"));
                    }
                    if let Some((at, _)) = got {
                        now = at;
                    }
                }
                if q.len() != model.len() {
                    return Err(format!("len mismatch: {} vs {}", q.len(), model.len()));
                }
            }
            // Drain both completely.
            while let Some(Reverse((at, _, p))) = model.pop() {
                let got = q.pop();
                if got != Some((at, p)) {
                    return Err(format!("drain mismatch: got {got:?} want {:?}", (at, p)));
                }
            }
            if !q.is_empty() {
                return Err("queue not empty after drain".into());
            }
            Ok(())
        });
    }
}
