//! Per-worker simulation state: execution queue, GPU cache, fetch/execute
//! occupancy, batch coalescing state, busy-time accounting, and the live
//! SST row.

use crate::config::ClusterConfig;
use crate::core::{Micros, ModelId, TaskId, WorkerId};
use crate::dfg::models::{batch_alpha, N_MODELS};
use crate::gpu::GpuCache;
use crate::metrics::{BusyTracker, WorkerMetrics};
use crate::net::BatchConfig;
use crate::sst::SstRow;
use crate::util::rng::Rng;
use std::collections::VecDeque;

/// A task instance sitting on (or running from) a worker's execution queue.
#[derive(Debug, Clone)]
pub struct QTask {
    pub job_idx: usize,
    pub task: TaskId,
    pub model: Option<ModelId>,
    /// Sampled actual runtime for this instance (jittered around R(t,w)).
    pub runtime_us: Micros,
    /// Set when this task triggered the in-flight model fetch (for cache
    /// hit/miss accounting).
    pub caused_fetch: bool,
}

pub struct SimWorker {
    pub id: WorkerId,
    pub gpu: GpuCache,
    queue: VecDeque<QTask>,
    /// The executing batch (one entry when batching is off). All members
    /// share one model and complete together at `exec_end`.
    running: Vec<QTask>,
    exec_end: Micros,
    fetching: Option<ModelId>,
    /// Batch-window hold deadline: a lone startable leader waits for
    /// queue-mates until this time before executing solo.
    hold_until: Option<Micros>,
    /// Incremental Σ runtime_us over the queue — keeps `ft_estimate` O(1)
    /// instead of re-summing the VecDeque per scheduler probe.
    queued_runtime_us: Micros,
    /// Per-model queued (count, Σ runtime_us) — the grouping the
    /// batching-aware drain estimate needs, maintained incrementally.
    queued_count: [u32; N_MODELS],
    queued_sum_us: [Micros; N_MODELS],
    busy: BusyTracker,
    executed: u64,
    rng: Rng,
}

impl SimWorker {
    pub fn new(id: WorkerId, cfg: &ClusterConfig, rng: Rng) -> SimWorker {
        let mut gpu = GpuCache::new(cfg.gpu_capacity, cfg.eviction);
        // Cache hit/miss/evict events flow into the trace via drain_log.
        gpu.set_logging(cfg.trace.enabled);
        SimWorker {
            id,
            gpu,
            queue: VecDeque::new(),
            running: Vec::new(),
            exec_end: 0,
            fetching: None,
            hold_until: None,
            queued_runtime_us: 0,
            queued_count: [0; N_MODELS],
            queued_sum_us: [0; N_MODELS],
            busy: BusyTracker::default(),
            executed: 0,
            rng,
        }
    }

    pub fn queue(&self) -> &VecDeque<QTask> {
        &self.queue
    }

    /// Append every queued task's model to `out` — the eviction planner's
    /// queue-lookahead window (§5.3.2) — into a caller-reused buffer, so a
    /// dispatch scan allocates nothing in steady state. Deduplicated in
    /// order of first appearance: repeats of one model never push other
    /// models out of the lookahead window.
    pub fn queue_models_into(&self, out: &mut Vec<ModelId>) {
        let mut seen: u64 = 0;
        for q in self.queue.iter() {
            if let Some(m) = q.model {
                if seen & (1 << m) == 0 {
                    seen |= 1 << m;
                    out.push(m);
                }
            }
        }
    }

    pub fn running(&self) -> Option<&QTask> {
        self.running.first()
    }

    /// All members of the executing batch (empty when idle).
    pub fn running_batch(&self) -> &[QTask] {
        &self.running
    }

    pub fn fetching(&self) -> Option<ModelId> {
        self.fetching
    }

    pub fn hold_until(&self) -> Option<Micros> {
        self.hold_until
    }

    pub fn set_hold(&mut self, deadline: Micros) {
        self.hold_until = Some(deadline);
    }

    pub fn clear_hold(&mut self) {
        self.hold_until = None;
    }

    pub fn enqueue(&mut self, qt: QTask) {
        self.queued_runtime_us += qt.runtime_us;
        if let Some(m) = qt.model {
            self.queued_count[m as usize] += 1;
            self.queued_sum_us[m as usize] += qt.runtime_us;
        }
        self.queue.push_back(qt);
    }

    pub fn mark_caused_fetch(&mut self, idx: usize) {
        self.queue[idx].caused_fetch = true;
    }

    pub fn begin_fetch(&mut self, m: ModelId) {
        debug_assert!(self.fetching.is_none());
        self.fetching = Some(m);
    }

    pub fn finish_fetch(&mut self, m: ModelId, now: Micros) {
        debug_assert_eq!(self.fetching, Some(m));
        self.fetching = None;
        self.gpu.insert(m, now);
    }

    /// Pop queue[idx], maintaining the incremental load accounting.
    fn take_queued(&mut self, idx: usize) -> QTask {
        let qt = self.queue.remove(idx).expect("queue index");
        self.queued_runtime_us -= qt.runtime_us;
        if let Some(m) = qt.model {
            self.queued_count[m as usize] -= 1;
            self.queued_sum_us[m as usize] -= qt.runtime_us;
        }
        qt
    }

    /// Pop queue[idx] and start executing it; pins its model.
    pub fn start_task(&mut self, idx: usize, now: Micros, end: Micros) -> &QTask {
        let qt = self.take_queued(idx);
        if let Some(m) = qt.model {
            self.gpu.pin(m);
        }
        self.busy.start(now);
        self.exec_end = end;
        self.executed += 1;
        self.hold_until = None;
        debug_assert!(self.running.is_empty());
        self.running.push(qt);
        &self.running[0]
    }

    /// Pop the given queue indices (ascending, all same-model) and start
    /// them as one batch ending at `end`. Each member pins the model once
    /// (pins are counted, so the batch holds exactly `len` pins).
    pub fn start_batch(&mut self, indices: &[usize], now: Micros, end: Micros) {
        debug_assert!(self.running.is_empty());
        debug_assert!(!indices.is_empty());
        for &idx in indices.iter().rev() {
            let qt = self.take_queued(idx);
            if let Some(m) = qt.model {
                self.gpu.pin(m);
            }
            self.running.push(qt);
        }
        self.running.reverse();
        self.busy.start(now);
        self.exec_end = end;
        self.executed += indices.len() as u64;
        self.hold_until = None;
    }

    pub fn finish_task(&mut self, now: Micros) -> QTask {
        debug_assert_eq!(self.running.len(), 1, "finish_task on a batch");
        let qt = self.running.pop().expect("finish without running");
        if let Some(m) = qt.model {
            self.gpu.unpin(m);
        }
        self.busy.stop(now);
        qt
    }

    /// Retire every member of the executing batch into `out` (a
    /// caller-recycled buffer, in start order), unpinning each.
    pub fn finish_batch(&mut self, now: Micros, out: &mut Vec<QTask>) {
        debug_assert!(!self.running.is_empty(), "finish without running");
        for qt in self.running.drain(..) {
            if let Some(m) = qt.model {
                self.gpu.unpin(m);
            }
            out.push(qt);
        }
        self.busy.stop(now);
    }

    /// Kill this worker (DESIGN.md §9): drain the executing batch and the
    /// whole queue into `out` — every entry is an orphan the recovery path
    /// re-places — and zero the incremental load accounting. `now` is the
    /// *crash* time (not the later detection time): busy accounting must
    /// not credit work past the death.
    pub fn crash(&mut self, now: Micros, out: &mut Vec<QTask>) {
        if !self.running.is_empty() {
            for qt in self.running.drain(..) {
                if let Some(m) = qt.model {
                    self.gpu.unpin(m);
                }
                out.push(qt);
            }
            self.busy.stop(now);
        }
        while let Some(qt) = self.queue.pop_front() {
            out.push(qt);
        }
        self.queued_runtime_us = 0;
        self.queued_count = [0; N_MODELS];
        self.queued_sum_us = [0; N_MODELS];
        self.fetching = None;
        self.hold_until = None;
    }

    /// Sample the actual runtime for a new task instance around `base` µs.
    pub fn sample_runtime(&mut self, base: f64, rel_std: f64) -> Micros {
        self.rng.jitter(base, rel_std, 100.0) as Micros
    }

    /// Fault-injection roll: does this task straggle?
    pub fn roll_straggler(&mut self, prob: f64) -> bool {
        self.rng.f64() < prob
    }

    /// FT(w): absolute time at which everything currently here finishes
    /// (running remainder + queue drain), §4.1. With batching off this is
    /// the plain runtime sum; with batching on, queued runtimes are grouped
    /// by model and drained through the coalescing cost curve, so peers see
    /// the shorter finish times batch-friendly queues actually achieve.
    // lint: hot-path
    // Called once per candidate worker per scheduling decision — the
    // single hottest read path in the simulator (PR 2/PR 3 perf work).
    pub fn ft_estimate(&self, now: Micros, batch: &BatchConfig) -> Micros {
        let base = if !self.running.is_empty() { self.exec_end.max(now) } else { now };
        if !batch.enabled() {
            return base + self.queued_runtime_us;
        }
        let mut modeled_sum: Micros = 0;
        let mut drain: Micros = 0;
        for m in 0..N_MODELS {
            let count = self.queued_count[m] as usize;
            if count == 0 {
                continue;
            }
            let sum = self.queued_sum_us[m];
            modeled_sum += sum;
            drain += batch.drain_estimate_us(count, sum, batch.alpha(batch_alpha(m as ModelId)));
        }
        // Model-less tasks (pre/post-processing vertices) never batch.
        base + drain + (self.queued_runtime_us - modeled_sum)
    }
    // lint: end-hot-path

    /// The worker's own live SST row (always current for itself).
    pub fn live_row(&self, now: Micros, batch: &BatchConfig) -> SstRow {
        SstRow {
            ft_us: self.ft_estimate(now, batch),
            cache_bitmap: self.gpu.bitmap(),
            free_cache_bytes: self.gpu.free_bytes(),
            load_pushed_at: now,
            cache_pushed_at: now,
        }
    }

    pub fn metrics(&mut self, span: Micros) -> WorkerMetrics {
        self.gpu.advance_time(span);
        let s = self.gpu.stats;
        WorkerMetrics {
            busy_us: self.busy.total(span),
            hits: s.hits,
            misses: s.misses,
            fetches: s.fetches,
            evictions: s.evictions,
            cache_byte_time: s.byte_time_integral,
            gpu_capacity: self.gpu.capacity(),
            active: self.executed > 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MS;

    fn worker() -> SimWorker {
        SimWorker::new(0, &ClusterConfig::default(), Rng::new(1))
    }

    fn qt(task: TaskId, model: Option<ModelId>, rt: Micros) -> QTask {
        QTask { job_idx: 0, task, model, runtime_us: rt, caused_fetch: false }
    }

    fn off() -> BatchConfig {
        BatchConfig::default()
    }

    #[test]
    fn ft_estimate_sums_queue() {
        let mut w = worker();
        w.enqueue(qt(0, None, 100 * MS));
        w.enqueue(qt(1, None, 50 * MS));
        assert_eq!(w.ft_estimate(1000, &off()), 1000 + 150 * MS);
    }

    #[test]
    fn ft_includes_running_remainder() {
        let mut w = worker();
        w.enqueue(qt(0, None, 100 * MS));
        w.start_task(0, 0, 100 * MS);
        w.enqueue(qt(1, None, 50 * MS));
        // At t=30ms: running until 100ms, then 50ms queued.
        assert_eq!(w.ft_estimate(30 * MS, &off()), 150 * MS);
    }

    #[test]
    fn ft_incremental_sum_tracks_dequeues() {
        let mut w = worker();
        w.enqueue(qt(0, Some(0), 10 * MS));
        w.enqueue(qt(1, None, 20 * MS));
        w.enqueue(qt(2, Some(0), 30 * MS));
        w.start_task(1, 0, 20 * MS); // pop the middle entry
        assert_eq!(w.ft_estimate(0, &off()), 20 * MS + 40 * MS);
        w.finish_task(20 * MS);
        assert_eq!(w.ft_estimate(20 * MS, &off()), 20 * MS + 40 * MS);
    }

    #[test]
    fn ft_estimate_discounts_batchable_queue() {
        use crate::dfg::models::DETR;
        let batch = BatchConfig { batch_max: 4, ..Default::default() };
        let mut w = worker();
        for t in 0..4 {
            w.enqueue(qt(t, Some(DETR), 10 * MS));
        }
        // alpha(detr)=0.5: one batch of 4 → 0.5·10 + 0.5·40 = 25 ms.
        assert_eq!(w.ft_estimate(0, &batch), 25 * MS);
        // Same queue without batching drains serially.
        assert_eq!(w.ft_estimate(0, &off()), 40 * MS);
    }

    #[test]
    fn ft_estimate_modelless_tasks_never_discounted() {
        let batch = BatchConfig { batch_max: 8, ..Default::default() };
        let mut w = worker();
        w.enqueue(qt(0, None, 10 * MS));
        w.enqueue(qt(1, None, 10 * MS));
        assert_eq!(w.ft_estimate(0, &batch), 20 * MS);
    }

    #[test]
    fn start_finish_roundtrip_pins() {
        use crate::dfg::models::OPT;
        let mut w = worker();
        w.gpu.insert(OPT, 0);
        w.enqueue(qt(0, Some(OPT), 10 * MS));
        w.start_task(0, 0, 10 * MS);
        // Pinned: eviction planning must refuse to evict OPT.
        assert!(w.gpu.plan_eviction(w.gpu.capacity(), &[]).is_none());
        w.finish_task(10 * MS);
        assert!(w.running().is_none());
    }

    #[test]
    fn batch_roundtrip_pins_and_drains_in_order() {
        use crate::dfg::models::OPT;
        let mut w = worker();
        w.gpu.insert(OPT, 0);
        for t in 0..3 {
            w.enqueue(qt(t, Some(OPT), 10 * MS));
        }
        w.start_batch(&[0, 1, 2], 0, 20 * MS);
        assert_eq!(w.running_batch().len(), 3);
        assert_eq!(w.queue().len(), 0);
        // All three members hold pins.
        assert!(w.gpu.plan_eviction(w.gpu.capacity(), &[]).is_none());
        let mut out = Vec::new();
        w.finish_batch(20 * MS, &mut out);
        assert_eq!(out.iter().map(|q| q.task).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(w.running().is_none());
        // Fully unpinned again: eviction may now plan against OPT.
        assert!(w.gpu.plan_eviction(w.gpu.capacity(), &[]).is_some());
    }

    #[test]
    fn queue_models_dedups_first_appearance() {
        use crate::dfg::models::{BART, DETR, OPT};
        let mut w = worker();
        w.enqueue(qt(0, Some(DETR), MS));
        w.enqueue(qt(1, Some(OPT), MS));
        w.enqueue(qt(2, Some(DETR), MS));
        w.enqueue(qt(3, None, MS));
        w.enqueue(qt(4, Some(BART), MS));
        let mut out = Vec::new();
        w.queue_models_into(&mut out);
        assert_eq!(out, vec![DETR, OPT, BART]);
    }

    #[test]
    fn hold_set_and_cleared_on_start() {
        let mut w = worker();
        w.enqueue(qt(0, Some(0), 10 * MS));
        w.set_hold(500);
        assert_eq!(w.hold_until(), Some(500));
        w.start_batch(&[0], 600, 10 * MS + 600);
        assert_eq!(w.hold_until(), None);
    }

    #[test]
    fn live_row_reflects_cache() {
        use crate::dfg::models::BART;
        let mut w = worker();
        w.gpu.insert(BART, 0);
        let row = w.live_row(5, &off());
        assert_eq!(row.cache_bitmap, 1 << BART);
        assert_eq!(row.ft_us, 5);
    }

    #[test]
    fn crash_drains_running_and_queue() {
        use crate::dfg::models::OPT;
        let mut w = worker();
        w.gpu.insert(OPT, 0);
        w.enqueue(qt(0, Some(OPT), 10 * MS));
        w.enqueue(qt(1, None, 20 * MS));
        w.enqueue(qt(2, Some(OPT), 30 * MS));
        w.start_task(0, 0, 10 * MS);
        w.begin_fetch(OPT);
        w.set_hold(500);
        let mut orphans = Vec::new();
        w.crash(5 * MS, &mut orphans);
        // Running member first (it was in flight), then the queue in order.
        assert_eq!(orphans.iter().map(|q| q.task).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(w.running().is_none());
        assert!(w.queue().is_empty());
        assert_eq!(w.fetching(), None);
        assert_eq!(w.hold_until(), None);
        assert_eq!(w.ft_estimate(5 * MS, &off()), 5 * MS, "load accounting zeroed");
        // Pins released: eviction may plan against OPT again.
        assert!(w.gpu.plan_eviction(w.gpu.capacity(), &[]).is_some());
        // Busy time stops at the crash instant.
        assert_eq!(w.metrics(10 * MS).busy_us, 5 * MS);
        // Crashing an idle worker is a no-op on busy accounting.
        let mut idle = worker();
        let mut none = Vec::new();
        idle.crash(1000, &mut none);
        assert!(none.is_empty());
    }

    #[test]
    fn sampled_runtime_near_base() {
        let mut w = worker();
        for _ in 0..100 {
            let r = w.sample_runtime(1_000_000.0, 0.1);
            assert!((700_000..=1_300_000).contains(&r), "r={r}");
        }
    }
}
