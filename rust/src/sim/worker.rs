//! Per-worker simulation state: execution queue, GPU cache, fetch/execute
//! occupancy, busy-time accounting, and the live SST row.

use crate::config::ClusterConfig;
use crate::core::{Micros, ModelId, TaskId, WorkerId};
use crate::gpu::GpuCache;
use crate::metrics::{BusyTracker, WorkerMetrics};
use crate::sst::SstRow;
use crate::util::rng::Rng;
use std::collections::VecDeque;

/// A task instance sitting on (or running from) a worker's execution queue.
#[derive(Debug, Clone)]
pub struct QTask {
    pub job_idx: usize,
    pub task: TaskId,
    pub model: Option<ModelId>,
    /// Sampled actual runtime for this instance (jittered around R(t,w)).
    pub runtime_us: Micros,
    /// Set when this task triggered the in-flight model fetch (for cache
    /// hit/miss accounting).
    pub caused_fetch: bool,
}

pub struct SimWorker {
    pub id: WorkerId,
    pub gpu: GpuCache,
    queue: VecDeque<QTask>,
    running: Option<QTask>,
    exec_end: Micros,
    fetching: Option<ModelId>,
    busy: BusyTracker,
    executed: u64,
    rng: Rng,
}

impl SimWorker {
    pub fn new(id: WorkerId, cfg: &ClusterConfig, rng: Rng) -> SimWorker {
        let mut gpu = GpuCache::new(cfg.gpu_capacity, cfg.eviction);
        // Cache hit/miss/evict events flow into the trace via drain_log.
        gpu.set_logging(cfg.trace.enabled);
        SimWorker {
            id,
            gpu,
            queue: VecDeque::new(),
            running: None,
            exec_end: 0,
            fetching: None,
            busy: BusyTracker::default(),
            executed: 0,
            rng,
        }
    }

    pub fn queue(&self) -> &VecDeque<QTask> {
        &self.queue
    }

    /// Append every queued task's model to `out` — the eviction planner's
    /// queue-lookahead window (§5.3.2) — into a caller-reused buffer, so a
    /// dispatch scan allocates nothing in steady state.
    pub fn queue_models_into(&self, out: &mut Vec<ModelId>) {
        out.extend(self.queue.iter().filter_map(|q| q.model));
    }

    pub fn running(&self) -> Option<&QTask> {
        self.running.as_ref()
    }

    pub fn fetching(&self) -> Option<ModelId> {
        self.fetching
    }

    pub fn enqueue(&mut self, qt: QTask) {
        self.queue.push_back(qt);
    }

    pub fn mark_caused_fetch(&mut self, idx: usize) {
        self.queue[idx].caused_fetch = true;
    }

    pub fn begin_fetch(&mut self, m: ModelId) {
        debug_assert!(self.fetching.is_none());
        self.fetching = Some(m);
    }

    pub fn finish_fetch(&mut self, m: ModelId, now: Micros) {
        debug_assert_eq!(self.fetching, Some(m));
        self.fetching = None;
        self.gpu.insert(m, now);
    }

    /// Pop queue[idx] and start executing it; pins its model.
    pub fn start_task(&mut self, idx: usize, now: Micros, end: Micros) -> &QTask {
        let qt = self.queue.remove(idx).expect("start_task index");
        if let Some(m) = qt.model {
            self.gpu.pin(m);
        }
        self.busy.start(now);
        self.exec_end = end;
        self.executed += 1;
        self.running = Some(qt);
        self.running.as_ref().unwrap()
    }

    pub fn finish_task(&mut self, now: Micros) -> QTask {
        let qt = self.running.take().expect("finish without running");
        if let Some(m) = qt.model {
            self.gpu.unpin(m);
        }
        self.busy.stop(now);
        qt
    }

    /// Sample the actual runtime for a new task instance around `base` µs.
    pub fn sample_runtime(&mut self, base: f64, rel_std: f64) -> Micros {
        self.rng.jitter(base, rel_std, 100.0) as Micros
    }

    /// Fault-injection roll: does this task straggle?
    pub fn roll_straggler(&mut self, prob: f64) -> bool {
        self.rng.f64() < prob
    }

    /// FT(w): absolute time at which everything currently here finishes
    /// (running task remainder + all queued runtimes), §4.1.
    pub fn ft_estimate(&self, now: Micros) -> Micros {
        let base = if self.running.is_some() { self.exec_end.max(now) } else { now };
        base + self.queue.iter().map(|q| q.runtime_us).sum::<Micros>()
    }

    /// The worker's own live SST row (always current for itself).
    pub fn live_row(&self, now: Micros) -> SstRow {
        SstRow {
            ft_us: self.ft_estimate(now),
            cache_bitmap: self.gpu.bitmap(),
            free_cache_bytes: self.gpu.free_bytes(),
            load_pushed_at: now,
            cache_pushed_at: now,
        }
    }

    pub fn metrics(&mut self, span: Micros) -> WorkerMetrics {
        self.gpu.advance_time(span);
        let s = self.gpu.stats;
        WorkerMetrics {
            busy_us: self.busy.total(span),
            hits: s.hits,
            misses: s.misses,
            fetches: s.fetches,
            evictions: s.evictions,
            cache_byte_time: s.byte_time_integral,
            gpu_capacity: self.gpu.capacity(),
            active: self.executed > 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MS;

    fn worker() -> SimWorker {
        SimWorker::new(0, &ClusterConfig::default(), Rng::new(1))
    }

    fn qt(task: TaskId, model: Option<ModelId>, rt: Micros) -> QTask {
        QTask { job_idx: 0, task, model, runtime_us: rt, caused_fetch: false }
    }

    #[test]
    fn ft_estimate_sums_queue() {
        let mut w = worker();
        w.enqueue(qt(0, None, 100 * MS));
        w.enqueue(qt(1, None, 50 * MS));
        assert_eq!(w.ft_estimate(1000), 1000 + 150 * MS);
    }

    #[test]
    fn ft_includes_running_remainder() {
        let mut w = worker();
        w.enqueue(qt(0, None, 100 * MS));
        w.start_task(0, 0, 100 * MS);
        w.enqueue(qt(1, None, 50 * MS));
        // At t=30ms: running until 100ms, then 50ms queued.
        assert_eq!(w.ft_estimate(30 * MS), 150 * MS);
    }

    #[test]
    fn start_finish_roundtrip_pins() {
        use crate::dfg::models::OPT;
        let mut w = worker();
        w.gpu.insert(OPT, 0);
        w.enqueue(qt(0, Some(OPT), 10 * MS));
        w.start_task(0, 0, 10 * MS);
        // Pinned: eviction planning must refuse to evict OPT.
        assert!(w.gpu.plan_eviction(w.gpu.capacity(), &[]).is_none());
        w.finish_task(10 * MS);
        assert!(w.running().is_none());
    }

    #[test]
    fn live_row_reflects_cache() {
        use crate::dfg::models::BART;
        let mut w = worker();
        w.gpu.insert(BART, 0);
        let row = w.live_row(5);
        assert_eq!(row.cache_bitmap, 1 << BART);
        assert_eq!(row.ft_us, 5);
    }

    #[test]
    fn sampled_runtime_near_base() {
        let mut w = worker();
        for _ in 0..100 {
            let r = w.sample_runtime(1_000_000.0, 0.1);
            assert!((700_000..=1_300_000).contains(&r), "r={r}");
        }
    }
}
