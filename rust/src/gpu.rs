//! GPU Memory Manager (paper §3.3 and §5.3).
//!
//! GPU memory is treated as a cache of ML models (*Navigator cache*). The
//! worker makes local fetch/evict decisions driven by its assigned tasks;
//! contents are published to peers as a 64-bit bitmap (§5.2). Two eviction
//! policies are implemented, matching §5.3: FIFO and queue-lookahead
//! (approximate Belady using the execution queue's known future).

use crate::core::{Micros, ModelId};
use crate::dfg::models::model_bytes;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Evict least-recently-*inserted* first (§5.3.1).
    Fifo,
    /// Look ahead `window` queued tasks; evict the resident model whose next
    /// use is farthest in the future (absent = farthest of all) (§5.3.2).
    QueueLookahead { window: usize },
}

impl Default for EvictionPolicy {
    fn default() -> Self {
        EvictionPolicy::QueueLookahead { window: 16 }
    }
}

/// Counters the Global State Monitor and Table 1 metrics read.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub fetches: u64,
    pub evictions: u64,
    /// Integral of resident bytes over time (for memory-utilization %).
    pub byte_time_integral: u128,
    pub last_update_us: Micros,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            return 1.0;
        }
        self.hits as f64 / (self.hits + self.misses) as f64
    }
}

/// What happened to the cache (for the `obs` event log).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheEventKind {
    Hit,
    Miss,
    Insert,
    Evict,
}

/// One logged cache state change. `free_bytes` is the free space *after*
/// the change took effect.
#[derive(Debug, Clone, Copy)]
pub struct CacheEvent {
    pub kind: CacheEventKind,
    pub model: ModelId,
    pub at_us: Micros,
    pub free_bytes: u64,
}

/// One worker's Navigator cache.
#[derive(Debug, Clone)]
pub struct GpuCache {
    capacity: u64,
    used: u64,
    /// Residents in insertion order (front = oldest, FIFO order).
    resident: Vec<ModelId>,
    /// Pin counts: models used by currently-executing tasks are unevictable.
    pins: [u16; 64],
    policy: EvictionPolicy,
    pub stats: CacheStats,
    /// Structured event log (only filled when `logging` is on).
    log: Vec<CacheEvent>,
    logging: bool,
}

impl GpuCache {
    pub fn new(capacity: u64, policy: EvictionPolicy) -> GpuCache {
        GpuCache {
            capacity,
            used: 0,
            resident: Vec::with_capacity(8),
            pins: [0; 64],
            policy,
            stats: CacheStats::default(),
            log: Vec::new(),
            logging: false,
        }
    }

    /// Enable structured event logging (see [`CacheEvent`]); off by default
    /// so untraced runs pay nothing.
    pub fn set_logging(&mut self, on: bool) {
        self.logging = on;
    }

    /// Drain the accumulated event log.
    pub fn drain_log(&mut self) -> Vec<CacheEvent> {
        std::mem::take(&mut self.log)
    }

    #[inline]
    fn log_event(&mut self, kind: CacheEventKind, m: ModelId, now: Micros) {
        if self.logging {
            let free_bytes = self.free_bytes();
            self.log.push(CacheEvent { kind, model: m, at_us: now, free_bytes });
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    /// AVC(w): available Navigator-cache memory (§4.1).
    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.used
    }

    pub fn contains(&self, m: ModelId) -> bool {
        self.resident.contains(&m)
    }

    pub fn resident(&self) -> &[ModelId] {
        &self.resident
    }

    /// The §5.2 cache-line encoding: bit i set ⇔ model i resident.
    pub fn bitmap(&self) -> u64 {
        self.resident.iter().fold(0u64, |b, &m| b | (1u64 << m))
    }

    pub fn pin(&mut self, m: ModelId) {
        debug_assert!(self.contains(m), "pin of non-resident model {m}");
        self.pins[m as usize] += 1;
    }

    pub fn unpin(&mut self, m: ModelId) {
        debug_assert!(self.pins[m as usize] > 0);
        self.pins[m as usize] -= 1;
    }

    fn pinned(&self, m: ModelId) -> bool {
        self.pins[m as usize] > 0
    }

    /// Advance the byte-time integral (call before any resident-set change
    /// and at metric sampling points).
    pub fn advance_time(&mut self, now: Micros) {
        if now > self.stats.last_update_us {
            let dt = (now - self.stats.last_update_us) as u128;
            self.stats.byte_time_integral += dt * self.used as u128;
            self.stats.last_update_us = now;
        }
    }

    /// Decide which models to evict to make room for `need` bytes, given the
    /// models required by upcoming queued tasks (`lookahead`, nearest first).
    /// Returns None if pinned residents make it impossible right now.
    pub fn plan_eviction(&self, need: u64, lookahead: &[ModelId]) -> Option<Vec<ModelId>> {
        if need <= self.free_bytes() {
            return Some(Vec::new());
        }
        let mut order: Vec<ModelId> = match self.policy {
            EvictionPolicy::Fifo => self.resident.clone(),
            EvictionPolicy::QueueLookahead { window } => {
                // Priority = position of next use in the (windowed) queue;
                // unused-in-window models sort first in eviction order.
                // Ties (both unused, or impossible same position) break by
                // FIFO insertion order.
                let horizon = lookahead.len().min(window);
                let next_use = |m: ModelId| -> usize {
                    lookahead[..horizon]
                        .iter()
                        .position(|&x| x == m)
                        .unwrap_or(usize::MAX)
                };
                let mut order: Vec<(usize, ModelId)> =
                    self.resident.iter().copied().enumerate().collect();
                order.sort_by(|a, b| next_use(b.1).cmp(&next_use(a.1)).then(a.0.cmp(&b.0)));
                order.into_iter().map(|(_, m)| m).collect()
            }
        };
        order.retain(|&m| !self.pinned(m));
        let mut freed = self.free_bytes();
        let mut victims = Vec::new();
        for m in order {
            if freed >= need {
                break;
            }
            freed += model_bytes(m);
            victims.push(m);
        }
        if freed >= need {
            Some(victims)
        } else {
            None
        }
    }

    /// Evict a specific model (must be resident and unpinned).
    pub fn evict(&mut self, m: ModelId, now: Micros) {
        self.advance_time(now);
        debug_assert!(!self.pinned(m), "evicting pinned model {m}");
        let pos = self.resident.iter().position(|&x| x == m).expect("evict non-resident");
        self.resident.remove(pos);
        self.used -= model_bytes(m);
        self.stats.evictions += 1;
        self.log_event(CacheEventKind::Evict, m, now);
    }

    /// Insert a fetched model (space must already be available).
    pub fn insert(&mut self, m: ModelId, now: Micros) {
        self.advance_time(now);
        debug_assert!(!self.contains(m), "double insert of model {m}");
        let sz = model_bytes(m);
        assert!(sz <= self.free_bytes(), "insert without room: {m}");
        self.resident.push(m);
        self.used += sz;
        self.stats.fetches += 1;
        self.log_event(CacheEventKind::Insert, m, now);
    }

    pub fn record_hit(&mut self, m: ModelId, now: Micros) {
        self.stats.hits += 1;
        self.log_event(CacheEventKind::Hit, m, now);
    }

    pub fn record_miss(&mut self, m: ModelId, now: Micros) {
        self.stats.misses += 1;
        self.log_event(CacheEventKind::Miss, m, now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::GB;
    use crate::dfg::models::*;

    fn cache(policy: EvictionPolicy) -> GpuCache {
        GpuCache::new(16 * GB, policy)
    }

    #[test]
    fn bitmap_encoding() {
        let mut c = cache(EvictionPolicy::Fifo);
        c.insert(OPT, 0);
        c.insert(BART, 0);
        assert_eq!(c.bitmap(), (1 << OPT) | (1 << BART));
    }

    #[test]
    fn fifo_evicts_oldest_first() {
        let mut c = cache(EvictionPolicy::Fifo);
        c.insert(OPT, 0); // 6 GB
        c.insert(MT5, 0); // 5 GB
        c.insert(MARIAN, 0); // 3 GB -> 14 GB used, 2 free
        let victims = c.plan_eviction(model_bytes(BART), &[]).unwrap(); // need 5
        assert_eq!(victims, vec![OPT]);
    }

    #[test]
    fn fifo_skips_pinned() {
        let mut c = cache(EvictionPolicy::Fifo);
        c.insert(OPT, 0);
        c.insert(MT5, 0);
        c.insert(MARIAN, 0);
        c.pin(OPT);
        let victims = c.plan_eviction(model_bytes(BART), &[]).unwrap();
        assert_eq!(victims, vec![MT5]);
    }

    #[test]
    fn impossible_eviction_returns_none() {
        let mut c = cache(EvictionPolicy::Fifo);
        c.insert(OPT, 0);
        c.insert(MT5, 0);
        c.pin(OPT);
        c.pin(MT5);
        // 5 GB free; need 6 with everything pinned.
        assert!(c.plan_eviction(6 * GB, &[]).is_none());
    }

    #[test]
    fn lookahead_protects_soon_needed_models() {
        let mut c = cache(EvictionPolicy::QueueLookahead { window: 8 });
        c.insert(OPT, 0); // oldest — FIFO would evict this
        c.insert(MT5, 0);
        c.insert(MARIAN, 0);
        // Queue says OPT needed next, MARIAN later, MT5 never.
        let victims = c.plan_eviction(model_bytes(BART), &[OPT, MARIAN]).unwrap();
        assert_eq!(victims, vec![MT5]);
    }

    #[test]
    fn lookahead_window_limits_vision() {
        let mut c = cache(EvictionPolicy::QueueLookahead { window: 1 });
        c.insert(OPT, 0);
        c.insert(MT5, 0);
        c.insert(MARIAN, 0);
        // MT5 appears beyond the window ⇒ treated as unused; OPT in window.
        let victims = c.plan_eviction(model_bytes(BART), &[OPT, MT5]).unwrap();
        // MT5 and MARIAN both "unused"; tie broken by FIFO ⇒ MT5 (older).
        assert_eq!(victims, vec![MT5]);
    }

    #[test]
    fn insert_evict_roundtrip_accounting() {
        let mut c = cache(EvictionPolicy::Fifo);
        c.insert(OPT, 0);
        assert_eq!(c.used(), 6 * GB);
        c.evict(OPT, 10);
        assert_eq!(c.used(), 0);
        assert_eq!(c.stats.evictions, 1);
        assert_eq!(c.stats.fetches, 1);
    }

    #[test]
    fn hit_rate_all_hits_when_empty_history() {
        let c = cache(EvictionPolicy::Fifo);
        assert_eq!(c.stats.hit_rate(), 1.0);
    }

    #[test]
    fn byte_time_integral_advances() {
        let mut c = cache(EvictionPolicy::Fifo);
        c.insert(OPT, 0);
        c.advance_time(1_000_000);
        assert_eq!(c.stats.byte_time_integral, 6 * GB as u128 * 1_000_000);
    }

    #[test]
    fn event_log_records_lifecycle_when_enabled() {
        let mut c = cache(EvictionPolicy::Fifo);
        c.set_logging(true);
        c.record_miss(OPT, 5);
        c.insert(OPT, 10);
        c.record_hit(OPT, 20);
        c.evict(OPT, 30);
        let log = c.drain_log();
        let kinds: Vec<CacheEventKind> = log.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                CacheEventKind::Miss,
                CacheEventKind::Insert,
                CacheEventKind::Hit,
                CacheEventKind::Evict
            ]
        );
        assert_eq!(log[1].free_bytes, 10 * GB);
        assert!(c.drain_log().is_empty(), "drain empties the log");
    }

    #[test]
    fn event_log_empty_when_disabled() {
        let mut c = cache(EvictionPolicy::Fifo);
        c.record_miss(OPT, 0);
        c.insert(OPT, 0);
        assert!(c.drain_log().is_empty());
        assert_eq!(c.stats.misses, 1, "counters still accumulate");
    }
}
