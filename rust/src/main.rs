//! Compass CLI — leader entrypoint.
//!
//! Subcommands (see README):
//!   simulate    run the discrete-event simulator on a Poisson workload
//!   experiment  regenerate a paper table/figure (fig6a..fig10, table1, all)
//!   serve       run the live coordinator on the AOT artifacts
//!   validate    compare simulator vs live coordinator (§5.4 methodology)
//!   models      list compiled artifacts and run handshakes
//!   lint        run compass-lint invariant checks over the crate sources

use compass::util::args::Args;

fn usage() -> ! {
    eprintln!(
        "usage: compass <command> [options]\n\
         \n\
         commands:\n\
         \x20 simulate    --scheduler compass|jit|heft|hash --rate R --jobs N\n\
         \x20             --workers W --seed S\n\
         \x20             [--batch-max B] [--batch-window-us U] [--batch-alpha A]\n\
         \x20             [--trace-out FILE] [--metrics-out FILE]\n\
         \x20             [fault flags, see below]\n\
         \x20 experiment  <fig6a|fig6b|fig6c|table1|fig7|fig8|fig9|fig10|batch|chaos|all>\n\
         \x20             [--quick] [--seed S] [--threads N]\n\
         \x20             [--trace-out FILE] [--metrics-out FILE]\n\
         \x20 serve       --rate R --jobs N [--workers W] [--artifacts DIR]\n\
         \x20             [--batch-max B] [--batch-window-us U] [--batch-alpha A]\n\
         \x20             [--trace-out FILE] [--metrics-out FILE]\n\
         \x20             [fault flags, see below]\n\
         \x20 validate    [--jobs N] [--artifacts DIR]\n\
         \x20 models      [--artifacts DIR]\n\
         \x20 lint        [--root DIR] [--json FILE]\n\
         \n\
         fault flags (simulate, serve; DESIGN.md \u{a7}9):\n\
         \x20 [--crash-rate P] [--crash W@MS,...] [--crash-window-ms MS]\n\
         \x20 [--slowdown-rate P] [--slowdown-factor F]\n\
         \x20 [--drop-prob P] [--delay-prob P] [--fetch-fail-prob P]\n\
         \x20 [--heartbeat-timeout-ms MS] [--fault-seed S]"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("simulate") => cmd_simulate(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("serve") => cmd_serve(&args),
        Some("validate") => cmd_validate(&args),
        Some("models") => cmd_models(&args),
        Some("lint") => cmd_lint(&args),
        Some("smoke-dump") => cmd_smoke_dump(args.positional.get(1).map(String::as_str).unwrap_or("bart")),
        _ => usage(),
    }
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    use compass::{ClusterConfig, SchedulerKind, Simulator};
    let kind = SchedulerKind::parse(args.get_or("scheduler", "compass"))
        .ok_or_else(|| anyhow::anyhow!("unknown scheduler"))?;
    let trace_out = args.get_path("trace-out");
    let metrics_out = args.get_path("metrics-out");
    let mut cfg = ClusterConfig::default()
        .with_scheduler(kind)
        .with_workers(args.get_usize("workers", 5))
        .with_seed(args.get_u64("seed", 42));
    // Either output needs the tracer running.
    cfg.trace.enabled |= trace_out.is_some() || metrics_out.is_some();
    cfg.cost.batch.batch_max = args.get_usize("batch-max", 1).max(1);
    cfg.cost.batch.window_us = args.get_u64("batch-window-us", cfg.cost.batch.window_us);
    if let Some(a) = args.get("batch-alpha") {
        cfg.cost.batch.alpha_override = Some(a.parse()?);
    }
    compass::fault::apply_fault_args(&mut cfg.fault, args)?;
    let seed = cfg.seed ^ 0x9e37;
    let jobs = compass::workload::poisson(
        args.get_f64("rate", 2.0),
        args.get_usize("jobs", 200),
        &[],
        seed,
    );
    let rep = Simulator::simulate(cfg, jobs);
    let m = &rep.metrics;
    println!("scheduler={} jobs={} events={}", kind.name(), m.jobs.len(), rep.events_processed);
    println!(
        "mean latency {:.2} s | mean slowdown {:.2} | median slowdown {:.2}",
        m.mean_latency_s(),
        m.mean_slowdown(),
        m.median_slowdown()
    );
    println!(
        "gpu util {:.0}% | mem util {:.0}% | energy {:.0} J | hit rate {:.1}% | active workers {}",
        m.gpu_utilization(),
        m.gpu_memory_utilization(),
        m.gpu_energy_joules(),
        m.cache_hit_rate(),
        m.active_workers()
    );
    if m.faults != compass::metrics::FaultStats::default() {
        println!(
            "faults: {} workers failed | {} tasks re-placed | {} retries | {} jobs failed | completion {:.1}%",
            m.faults.workers_failed,
            m.faults.tasks_re_placed,
            m.faults.task_retries,
            m.faults.jobs_failed,
            m.completion_rate()
        );
    }
    compass::obs::write_outputs(
        &rep.trace,
        &rep.metrics,
        trace_out.as_deref(),
        metrics_out.as_deref(),
    )?;
    if let Some(p) = &trace_out {
        println!("chrome trace ({} events) written to {}", rep.trace.events.len(), p.display());
    }
    if let Some(p) = &metrics_out {
        println!("metrics snapshot written to {}", p.display());
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    compass::exp::run(which, args)
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    compass::coordinator::cli_serve(args)
}

fn cmd_validate(args: &Args) -> anyhow::Result<()> {
    compass::exp::validate_cli(args)
}

fn cmd_models(args: &Args) -> anyhow::Result<()> {
    let default_dir = compass::runtime::artifacts_dir();
    let dir = std::path::PathBuf::from(
        args.get_or("artifacts", default_dir.to_str().unwrap_or("artifacts")),
    );
    let rt = compass::runtime::Runtime::load(&dir)?;
    println!("{} models loaded + handshaken from {}", rt.len(), dir.display());
    for name in rt.names() {
        let m = rt.get(name).unwrap();
        println!(
            "  {:10} id={} seq={} d={} ({})",
            name,
            m.meta.model_id,
            m.meta.seq_len,
            m.meta.d_model,
            m.meta.path.display()
        );
    }
    Ok(())
}

/// `compass lint` — run the invariant checker over the crate sources
/// (DESIGN.md §8). Exits nonzero when any finding fires, which is what
/// makes the CI `compass-lint` job a gate.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    let root = args
        .get_path("root")
        .unwrap_or_else(|| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src"));
    let report = compass::lint::lint_tree(&root)?;
    if let Some(p) = args.get_path("json") {
        std::fs::write(&p, report.to_json())?;
        println!("lint report written to {}", p.display());
    }
    print!("{}", report.render());
    if !report.clean() {
        std::process::exit(1);
    }
    Ok(())
}

/// Hidden diagnostic: dump a model's smoke output as JSON floats.
#[allow(dead_code)]
fn cmd_smoke_dump(name: &str) -> anyhow::Result<()> {
    let rt = compass::runtime::Runtime::load_unchecked(&compass::runtime::artifacts_dir())?;
    let m = rt.get(name).ok_or_else(|| anyhow::anyhow!("no model {name}"))?;
    let y = m.execute(&m.smoke_input())?;
    println!("{:?}", y);
    Ok(())
}
