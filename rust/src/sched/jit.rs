//! Just-in-time baseline (§6.2.1).
//!
//! No planning phase at all: each task is placed only when it becomes
//! dispatchable, on the worker offering the earliest start time —
//! worker queue wait (from the Global State Monitor) + model fetch time +
//! intermediate-data transfer. Optimizes each task in isolation; the paper
//! shows it beats HEFT/Hash under load but loses to Compass for lack of
//! intra-job coordination.

use super::{arrival_at, AssignCtx, ClusterView, DecisionProbe, Scheduler};
use crate::config::SchedulerKind;
use crate::core::{Micros, WorkerId};
use crate::dfg::models::model_bytes;
use crate::dfg::{Adfg, Dfg, Job};

pub struct Jit;

impl Scheduler for Jit {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Jit
    }

    /// JIT does not plan: every slot stays unassigned.
    fn plan_probed(
        &self,
        _job: &Job,
        dfg: &Dfg,
        _view: &ClusterView,
        _probe: &mut DecisionProbe,
    ) -> Adfg {
        Adfg::unassigned(dfg.len())
    }

    fn assign_probed(
        &self,
        ctx: &AssignCtx,
        view: &ClusterView,
        probe: &mut DecisionProbe,
    ) -> WorkerId {
        let mut best = view.fallback_alive(view.self_worker);
        let mut best_start = Micros::MAX;
        for w in 0..view.n_workers() {
            if !view.alive(w) {
                continue;
            }
            // Inputs all exist (the task just became dispatchable), so they
            // are available `now` at their holders — no per-call vector.
            let arrive = arrival_at(view, ctx.pred_outputs, view.now, w);
            let td_model = match ctx.dfg.vertices[ctx.task].model {
                Some(m) if view.rows[w].cache_bitmap & (1u64 << m) == 0 => {
                    view.cost.td_model(model_bytes(m))
                }
                _ => 0,
            };
            let start = view.ft(w).max(arrive) + td_model;
            probe.offer(w, start);
            if start < best_start {
                best_start = start;
                best = w;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{GB, SEC};
    use crate::dfg::models::OPT;
    use crate::dfg::pipelines;
    use crate::net::CostModel;
    use crate::sst::SstRow;

    fn ctx_for<'a>(
        job: &'a Job,
        dfg: &'a Dfg,
        task: usize,
        outs: &'a [(usize, u64)],
    ) -> AssignCtx<'a> {
        AssignCtx { job, dfg, task, planned: None, pred_outputs: outs }
    }

    #[test]
    fn plan_is_empty() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let rows = vec![SstRow::default(); 2];
        let speed = vec![1.0; 2];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &crate::sched::PlanCell::default(),
        };
        let job = Job { id: 1, kind: dfg.kind, arrival_us: 0, input_bytes: 100 };
        let adfg = Jit.plan(&job, &dfg, &view);
        assert!(adfg.assignment.iter().all(|a| a.is_none()));
    }

    #[test]
    fn picks_cached_worker_over_idle_one() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost); // task 0 needs OPT (6 GB ≈ 0.5 s fetch)
        let mut rows = vec![SstRow::default(); 2];
        rows[1].cache_bitmap = 1 << OPT;
        rows[1].free_cache_bytes = 10 * GB;
        rows[0].free_cache_bytes = 16 * GB;
        let speed = vec![1.0; 2];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &crate::sched::PlanCell::default(),
        };
        let job = Job { id: 1, kind: dfg.kind, arrival_us: 0, input_bytes: 100 };
        let outs = [(0usize, 100u64)];
        let w = Jit.assign(&ctx_for(&job, &dfg, 0, &outs), &view);
        assert_eq!(w, 1);
    }

    #[test]
    fn avoids_long_queue() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let mut rows = vec![SstRow::default(); 2];
        rows[0].ft_us = 30 * SEC;
        let speed = vec![1.0; 2];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &crate::sched::PlanCell::default(),
        };
        let job = Job { id: 1, kind: dfg.kind, arrival_us: 0, input_bytes: 100 };
        let outs = [(0usize, 100u64)];
        // Glue task (no model) — pure queue comparison.
        let w = Jit.assign(&ctx_for(&job, &dfg, 2, &outs), &view);
        assert_eq!(w, 1);
    }
}
