//! Scheduling: the Compass/Navigator algorithm (§4) and the §6.2.1
//! baselines (JIT, classic HEFT, Hash), behind one trait that both the
//! simulator and the live coordinator drive.
//!
//! Two hooks mirror the paper's two phases:
//!   * `plan` — job-instance planning, run once by the worker that received
//!     the request; produces the initial ADFG (Algorithm 1 for Compass).
//!   * `assign` — called when a task becomes dispatchable (all predecessors
//!     finished); this is where dynamic adjustment (Algorithm 2) happens.
//!     Schedulers without an adjustment phase return the planned worker;
//!     JIT defers all placement to this hook.

pub mod compass;
pub mod hash;
pub mod heft;
pub mod jit;

use crate::config::{ClusterConfig, SchedulerKind};
use crate::core::{Micros, TaskId, WorkerId};
use crate::dfg::{Adfg, Dfg, Job};
use crate::net::CostModel;
use crate::obs::CandidateSet;
use crate::sst::SstRow;

/// Reusable scratch for the planning hot paths (Algorithms 1/2): the
/// per-worker finish-time map and per-task finish times that `plan` needs
/// per job. Hoisted out of the schedulers so a steady-state decision does
/// zero heap allocation — buffers are cleared and refilled, never freed.
#[derive(Debug, Default)]
pub struct PlanScratch {
    /// worker_FT_map (Alg. 1 line 2); HEFT reuses it as its availability
    /// map.
    pub worker_ft: Vec<Micros>,
    /// FT(t) of already-placed tasks (Alg. 1 line 10).
    pub task_ft: Vec<Micros>,
    /// Per-(worker, model) count of tasks this plan already placed, indexed
    /// `w * N_MODELS + m`. Only maintained when batching is enabled: lets
    /// Algorithm 1 charge the discounted marginal runtime (and a zero model
    /// fetch) when a task would join a batch the plan itself is building.
    pub planned_models: Vec<u32>,
}

/// Interior-mutability cell carrying [`PlanScratch`] through the shared
/// `&ClusterView`, keeping `plan`/`assign` `&self`. Each deciding thread
/// owns its own cell (`RefCell` is `Send` but not `Sync`: the simulator
/// and every live worker thread hold one apiece), so the stateless
/// `Scheduler: Send + Sync` contract is untouched.
pub type PlanCell = std::cell::RefCell<PlanScratch>;

/// What a scheduling decision can see: the *published* SST rows (with the
/// deciding worker's own row refreshed live — a worker always knows its own
/// state), plus static cluster facts.
pub struct ClusterView<'a> {
    pub now: Micros,
    /// The worker running this scheduling decision.
    pub self_worker: WorkerId,
    /// Published SST rows; `rows[self_worker]` is live.
    pub rows: &'a [SstRow],
    pub cost: &'a CostModel,
    /// Per-worker speed factor; R(t,w) = R(t) * speed[w].
    pub speed: &'a [f64],
    /// Caller-owned reusable planning scratch (one per deciding thread).
    pub scratch: &'a PlanCell,
}

impl<'a> ClusterView<'a> {
    pub fn n_workers(&self) -> usize {
        self.rows.len()
    }

    /// R(t, w): expected runtime of task t on worker w (§4.1).
    #[inline]
    pub fn r(&self, dfg: &Dfg, t: TaskId, w: WorkerId) -> Micros {
        (dfg.vertices[t].mean_runtime_us as f64 * self.speed[w]) as Micros
    }

    /// FT(w): absolute estimated finish time of w's queue, clamped to now
    /// (a queue can't finish in the past).
    #[inline]
    pub fn ft(&self, w: WorkerId) -> Micros {
        self.rows[w].ft_us.max(self.now)
    }

    /// Wait time on w's queue as estimated from the published row.
    #[inline]
    pub fn wait(&self, w: WorkerId) -> Micros {
        self.rows[w].ft_us.saturating_sub(self.now)
    }

    /// Is w schedulable? A poisoned row (worker declared dead by the
    /// failure detector, DESIGN.md §9) masks the worker out of every
    /// scheduler. Callers must check this *before* any finish-time
    /// arithmetic: a poisoned row's `ft_us` is the `u64::MAX` sentinel.
    #[inline]
    pub fn alive(&self, w: WorkerId) -> bool {
        !self.rows[w].poisoned()
    }

    /// `w` itself when alive — the identity in a failure-free cluster —
    /// otherwise the next alive worker on the ring. Used by the schedulers
    /// without a scoring loop (Hash, locked HEFT assignments). Returns `w`
    /// unchanged if no worker is alive; callers only dispatch while at
    /// least one survives.
    #[inline]
    pub fn fallback_alive(&self, w: WorkerId) -> WorkerId {
        if self.alive(w) {
            return w;
        }
        let n = self.n_workers();
        for i in 1..n {
            let c = (w + i) % n;
            if self.alive(c) {
                return c;
            }
        }
        w
    }
}

/// Context for an `assign` call: task t has just become dispatchable.
pub struct AssignCtx<'a> {
    pub job: &'a Job,
    pub dfg: &'a Dfg,
    pub task: TaskId,
    /// The ADFG's current placement for this task (None only under JIT).
    pub planned: Option<WorkerId>,
    /// (worker currently holding the data, bytes) for each input of t.
    /// For the entry task this is the client input at the ingress worker.
    pub pred_outputs: &'a [(WorkerId, u64)],
}

/// Collects the candidate workers a scheduler scored while deciding, for
/// the observability layer ([`crate::obs`]). An inactive probe makes every
/// hook a branch-and-return, so uninstrumented callers pay ~nothing.
///
/// Decisions are grouped per task: `begin(t)` opens a task's candidate set
/// (flushing the previous one), `offer(w, score)` records one scored
/// candidate, and `take_records` / `take_single` hand the sets back to the
/// caller that emits [`crate::obs::TraceEvent::Decision`] events.
#[derive(Debug, Default)]
pub struct DecisionProbe {
    active: bool,
    started: bool,
    cur_task: TaskId,
    cur: CandidateSet,
    records: Vec<(TaskId, CandidateSet)>,
}

impl DecisionProbe {
    /// The no-op probe used by the default `plan`/`assign` trait methods.
    pub fn off() -> DecisionProbe {
        DecisionProbe::default()
    }

    pub fn on() -> DecisionProbe {
        DecisionProbe { active: true, ..DecisionProbe::default() }
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Open the candidate set for `task`, flushing any previous one.
    #[inline]
    pub fn begin(&mut self, task: TaskId) {
        if !self.active {
            return;
        }
        self.flush();
        self.started = true;
        self.cur_task = task;
    }

    /// Record one scored candidate (lower score = better).
    #[inline]
    pub fn offer(&mut self, w: WorkerId, score_us: Micros) {
        if !self.active {
            return;
        }
        // Schedulers that only ever decide one task (assign hooks) may skip
        // `begin`; open an anonymous set for them.
        self.started = true;
        self.cur.push(w as u16, score_us);
    }

    fn flush(&mut self) {
        if self.started {
            self.records.push((self.cur_task, self.cur));
            self.cur = CandidateSet::default();
            self.started = false;
        }
    }

    /// All (task, candidates) sets recorded since the last take.
    pub fn take_records(&mut self) -> Vec<(TaskId, CandidateSet)> {
        self.flush();
        std::mem::take(&mut self.records)
    }

    /// The single candidate set of a one-task decision (assign hooks).
    pub fn take_single(&mut self) -> CandidateSet {
        self.flush();
        self.records.pop().map(|(_, c)| c).unwrap_or_default()
    }
}

pub trait Scheduler: Send + Sync {
    fn kind(&self) -> SchedulerKind;

    /// Job-instance planning phase with decision probing: produce the
    /// initial ADFG, offering every scored candidate to `probe`.
    fn plan_probed(
        &self,
        job: &Job,
        dfg: &Dfg,
        view: &ClusterView,
        probe: &mut DecisionProbe,
    ) -> Adfg;

    /// Task is dispatchable: confirm or change its worker, offering every
    /// scored candidate to `probe`.
    fn assign_probed(
        &self,
        ctx: &AssignCtx,
        view: &ClusterView,
        probe: &mut DecisionProbe,
    ) -> WorkerId;

    /// Job-instance planning phase: produce the initial ADFG.
    fn plan(&self, job: &Job, dfg: &Dfg, view: &ClusterView) -> Adfg {
        self.plan_probed(job, dfg, view, &mut DecisionProbe::off())
    }

    /// Task is dispatchable: confirm or change its worker.
    fn assign(&self, ctx: &AssignCtx, view: &ClusterView) -> WorkerId {
        self.assign_probed(ctx, view, &mut DecisionProbe::off())
    }
}

/// Instantiate the configured scheduler.
pub fn build(cfg: &ClusterConfig) -> Box<dyn Scheduler> {
    match cfg.scheduler {
        SchedulerKind::Compass => Box::new(compass::Compass::new(cfg.compass)),
        SchedulerKind::Jit => Box::new(jit::Jit),
        SchedulerKind::Heft => Box::new(heft::Heft),
        SchedulerKind::Hash => Box::new(hash::HashSched),
    }
}

/// Shared estimate: earliest arrival of all of t's inputs at worker w,
/// given where each input currently lives. `avail_us` is the absolute time
/// the inputs become available at their holders — on the adjust path every
/// input already exists (t just became dispatchable), so a single scalar
/// replaces the per-input vector the callers used to allocate.
pub fn arrival_at(
    view: &ClusterView,
    inputs: &[(WorkerId, u64)],
    avail_us: Micros,
    w: WorkerId,
) -> Micros {
    inputs
        .iter()
        .map(|&(src, bytes)| avail_us + view.cost.td_input(bytes, src, w))
        .max()
        .unwrap_or(view.now)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{MS, SEC};
    use crate::dfg::pipelines;
    use crate::sst::SstRow;

    fn rows(n: usize) -> Vec<SstRow> {
        vec![SstRow::default(); n]
    }

    #[test]
    fn view_ft_clamps_to_now() {
        let cost = CostModel::default();
        let speed = vec![1.0; 2];
        let mut r = rows(2);
        r[0].ft_us = 100;
        let view = ClusterView {
            now: 5 * SEC,
            self_worker: 0,
            rows: &r,
            cost: &cost,
            speed: &speed,
            scratch: &PlanCell::default(),
        };
        assert_eq!(view.ft(0), 5 * SEC);
        assert_eq!(view.wait(0), 0);
    }

    #[test]
    fn view_r_scales_with_speed() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let speed = vec![1.0, 2.0];
        let r = rows(2);
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &r,
            cost: &cost,
            speed: &speed,
            scratch: &PlanCell::default(),
        };
        assert_eq!(view.r(&dfg, 0, 1), 2 * view.r(&dfg, 0, 0));
    }

    #[test]
    fn arrival_accounts_colocated_free() {
        let cost = CostModel::default();
        let speed = vec![1.0; 3];
        let r = rows(3);
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &r,
            cost: &cost,
            speed: &speed,
            scratch: &PlanCell::default(),
        };
        // The big input lives on worker 1; the small one on worker 2. Both
        // become available at t = 20 ms.
        let inputs = [(1usize, 8_000_000u64), (2usize, 1_000_000u64)];
        let avail = 20 * MS;
        // At worker 1 the dominant input is free (colocated).
        let a1 = arrival_at(&view, &inputs, avail, 1);
        let a2 = arrival_at(&view, &inputs, avail, 0);
        assert!(a1 < a2, "a1={a1} a2={a2}");
        assert!(a1 >= 20 * MS);
    }

    #[test]
    fn build_constructs_each_kind() {
        for kind in SchedulerKind::ALL {
            let cfg = ClusterConfig::default().with_scheduler(kind);
            assert_eq!(build(&cfg).kind(), kind);
        }
    }

    #[test]
    fn alive_masking_and_ring_fallback() {
        let cost = CostModel::default();
        let speed = vec![1.0; 4];
        let mut r = rows(4);
        r[1].ft_us = crate::sst::POISONED_FT;
        r[2].ft_us = crate::sst::POISONED_FT;
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &r,
            cost: &cost,
            speed: &speed,
            scratch: &PlanCell::default(),
        };
        assert!(view.alive(0) && !view.alive(1) && !view.alive(2) && view.alive(3));
        assert_eq!(view.fallback_alive(0), 0, "alive worker is the identity");
        assert_eq!(view.fallback_alive(1), 3, "ring-probes past dead peers");
        assert_eq!(view.fallback_alive(2), 3);
    }

    #[test]
    fn every_scheduler_avoids_poisoned_worker() {
        use crate::dfg::pipelines;
        let cost = CostModel::default();
        let dfg = pipelines::translation(&cost);
        let mut r = rows(4);
        r[2].ft_us = crate::sst::POISONED_FT;
        let speed = vec![1.0; 4];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &r,
            cost: &cost,
            speed: &speed,
            scratch: &PlanCell::default(),
        };
        for kind in SchedulerKind::ALL {
            let cfg = ClusterConfig::default().with_scheduler(kind);
            let sched = build(&cfg);
            for id in 0..64u64 {
                let job = Job { id, kind: dfg.kind, arrival_us: 0, input_bytes: 100 };
                let adfg = sched.plan(&job, &dfg, &view);
                for t in 0..dfg.len() {
                    assert_ne!(adfg.get(t), Some(2), "{kind:?} planned onto dead worker");
                    let outs = [(0usize, 100u64)];
                    let ctx = AssignCtx {
                        job: &job,
                        dfg: &dfg,
                        task: t,
                        // Force the dead worker as the planned slot: every
                        // assign hook must re-place it.
                        planned: Some(2),
                        pred_outputs: &outs,
                    };
                    assert_ne!(sched.assign(&ctx, &view), 2, "{kind:?} assigned dead worker");
                }
            }
        }
    }

    #[test]
    fn inactive_probe_records_nothing() {
        let mut p = DecisionProbe::off();
        p.begin(3);
        p.offer(1, 100);
        assert!(p.take_records().is_empty());
        assert!(p.take_single().is_empty());
    }

    #[test]
    fn probe_groups_offers_per_task() {
        let mut p = DecisionProbe::on();
        p.begin(0);
        p.offer(0, 50);
        p.offer(1, 40);
        p.begin(1);
        p.offer(2, 30);
        let recs = p.take_records();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].0, 0);
        assert_eq!(recs[0].1.len(), 2);
        assert_eq!(recs[1].0, 1);
        assert!(recs[1].1.contains(2));
        // Taking again yields nothing.
        assert!(p.take_records().is_empty());
    }

    #[test]
    fn every_scheduler_offers_candidates() {
        use crate::dfg::pipelines;
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let r = rows(3);
        let speed = vec![1.0; 3];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &r,
            cost: &cost,
            speed: &speed,
            scratch: &PlanCell::default(),
        };
        let job = Job { id: 1, kind: dfg.kind, arrival_us: 0, input_bytes: 100 };
        for kind in SchedulerKind::ALL {
            let cfg = ClusterConfig::default().with_scheduler(kind);
            let sched = build(&cfg);
            let mut probe = DecisionProbe::on();
            let adfg = sched.plan_probed(&job, &dfg, &view, &mut probe);
            let plan_recs = probe.take_records();
            if kind != SchedulerKind::Jit {
                assert_eq!(plan_recs.len(), dfg.len(), "{kind:?} plans every task");
                assert!(plan_recs.iter().all(|(_, c)| !c.is_empty()));
            }
            let outs = [(0usize, 100u64)];
            let ctx = AssignCtx {
                job: &job,
                dfg: &dfg,
                task: 1,
                planned: adfg.get(1),
                pred_outputs: &outs,
            };
            let mut probe = DecisionProbe::on();
            let chosen = sched.assign_probed(&ctx, &view, &mut probe);
            let cands = probe.take_single();
            assert!(!cands.is_empty(), "{kind:?} assign offers candidates");
            assert!(cands.contains(chosen as u16), "{kind:?} chosen worker is a candidate");
        }
    }

    #[test]
    fn default_plan_matches_probed() {
        let cost = CostModel::default();
        let dfg = crate::dfg::pipelines::translation(&cost);
        let r = rows(4);
        let speed = vec![1.0; 4];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &r,
            cost: &cost,
            speed: &speed,
            scratch: &PlanCell::default(),
        };
        let job = Job { id: 9, kind: dfg.kind, arrival_us: 0, input_bytes: 100 };
        let cfg = ClusterConfig::default();
        let sched = build(&cfg);
        let a = sched.plan(&job, &dfg, &view);
        let b = sched.plan_probed(&job, &dfg, &view, &mut DecisionProbe::on());
        assert_eq!(a.assignment, b.assignment, "probing must not change decisions");
    }
}
