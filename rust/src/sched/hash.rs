//! Hash load-balancing baseline (§6.2.1).
//!
//! Task placement by hashing the task name combined with the request id —
//! uniform distribution across workers, no state consulted. This is the
//! load balancer Cascade shipped before Navigator replaced it (§5), and
//! the scalability foil of Figure 10.

use super::{AssignCtx, ClusterView, DecisionProbe, Scheduler};
use crate::config::SchedulerKind;
use crate::core::{hash_pair, WorkerId};
use crate::dfg::{Adfg, Dfg, Job};

pub struct HashSched;

impl Scheduler for HashSched {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Hash
    }

    fn plan_probed(
        &self,
        job: &Job,
        dfg: &Dfg,
        view: &ClusterView,
        probe: &mut DecisionProbe,
    ) -> Adfg {
        let mut adfg = Adfg::unassigned(dfg.len());
        for t in 0..dfg.len() {
            // Stateless hashing cannot see deaths, so liveness is a ring
            // fallback bolted on after the hash (the identity while every
            // worker is alive).
            let w = view.fallback_alive(
                (hash_pair(job.id, t as u64) % view.n_workers() as u64) as WorkerId,
            );
            probe.begin(t);
            probe.offer(w, 0);
            adfg.set(t, w);
        }
        adfg
    }

    fn assign_probed(
        &self,
        ctx: &AssignCtx,
        view: &ClusterView,
        probe: &mut DecisionProbe,
    ) -> WorkerId {
        let planned = view.fallback_alive(ctx.planned.expect("hash plans every task"));
        probe.offer(planned, 0);
        planned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::CostModel;
    use crate::sst::SstRow;

    #[test]
    fn distribution_is_roughly_uniform() {
        let cost = CostModel::default();
        let dfg = crate::dfg::pipelines::translation(&cost);
        let rows = vec![SstRow::default(); 4];
        let speed = vec![1.0; 4];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &crate::sched::PlanCell::default(),
        };
        let mut counts = [0usize; 4];
        for id in 0..4000u64 {
            let job = Job { id, kind: dfg.kind, arrival_us: 0, input_bytes: 10 };
            let adfg = HashSched.plan(&job, &dfg, &view);
            for t in 0..dfg.len() {
                counts[adfg.get(t).unwrap()] += 1;
            }
        }
        let total: usize = counts.iter().sum();
        let expect = total / 4;
        for c in counts {
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 5) as u64,
                "skewed: {counts:?}"
            );
        }
    }

    #[test]
    fn deterministic_per_job() {
        let cost = CostModel::default();
        let dfg = crate::dfg::pipelines::vpa(&cost);
        let rows = vec![SstRow::default(); 3];
        let speed = vec![1.0; 3];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &crate::sched::PlanCell::default(),
        };
        let job = Job { id: 42, kind: dfg.kind, arrival_us: 0, input_bytes: 10 };
        let a = HashSched.plan(&job, &dfg, &view);
        let b = HashSched.plan(&job, &dfg, &view);
        assert_eq!(a.assignment, b.assignment);
    }
}
