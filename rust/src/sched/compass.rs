//! The Compass/Navigator scheduler: job planning (Algorithm 1) and dynamic
//! adjustment (Algorithm 2), §4 of the paper.
//!
//! The planning phase extends HEFT with (a) worker queue load FT(w) from the
//! SST, and (b) model locality via the published cache bitmaps (Eq. 2,
//! including the eviction penalty). Dynamic adjustment re-places a non-join
//! task whose planned worker's queue wait exceeds `R(t,w) × threshold`.
//! Both ablation switches of §6.3.1 are honored via `CompassConfig`.

use super::{arrival_at, AssignCtx, ClusterView, DecisionProbe, PlanScratch, Scheduler};
use crate::config::{CompassConfig, SchedulerKind};
use crate::core::{Micros, TaskId, WorkerId};
use crate::dfg::models::{mean_model_bytes, model_bytes};
use crate::dfg::{Adfg, Dfg, Job};

pub struct Compass {
    cfg: CompassConfig,
}

impl Compass {
    pub fn new(cfg: CompassConfig) -> Compass {
        Compass { cfg }
    }

    /// Eq. 2: TD_model(t, w) with the three arms — resident, fits, evicts.
    /// With model locality disabled (ablation), the estimate degenerates to
    /// a uniform fetch cost: cache contents no longer differentiate workers.
    /// `fetch` is the worker-invariant PCIe cost, hoisted by callers out of
    /// their O(W) loops.
    #[inline]
    fn td_model_arms(
        &self,
        m: crate::core::ModelId,
        fetch: Micros,
        w: WorkerId,
        view: &ClusterView,
    ) -> Micros {
        if !self.cfg.model_locality {
            return fetch;
        }
        let row = &view.rows[w];
        if row.cache_bitmap & (1u64 << m) != 0 {
            0
        } else if model_bytes(m) <= row.free_cache_bytes {
            fetch
        } else {
            // Eviction penalty: the displaced model will likely need to be
            // re-fetched soon (§4.2.2 "Eviction penalty" discussion).
            let penalty = (view.cost.td_model(mean_model_bytes()) as f64
                * self.cfg.eviction_penalty_factor) as Micros;
            fetch + penalty
        }
    }

    fn td_model_est(&self, dfg: &Dfg, t: TaskId, w: WorkerId, view: &ClusterView) -> Micros {
        let Some(m) = dfg.vertices[t].model else { return 0 };
        self.td_model_arms(m, view.cost.td_model(model_bytes(m)), w, view)
    }
}

impl Scheduler for Compass {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Compass
    }

    /// Algorithm 1 — Job Planning.
    fn plan_probed(
        &self,
        job: &Job,
        dfg: &Dfg,
        view: &ClusterView,
        probe: &mut DecisionProbe,
    ) -> Adfg {
        let n = dfg.len();
        let w_count = view.n_workers();
        let batch = &view.cost.batch;
        let batching = batch.enabled();
        // Line 2: worker_FT_map from the Global State Monitor — filled into
        // the caller-owned scratch, so planning allocates nothing per job
        // beyond the returned ADFG (which outlives this call as job state).
        let mut scratch = view.scratch.borrow_mut();
        let PlanScratch { worker_ft, task_ft, planned_models } = &mut *scratch;
        worker_ft.clear();
        worker_ft.extend((0..w_count).map(|w| view.ft(w)));
        task_ft.clear();
        task_ft.resize(n, 0);
        if batching {
            planned_models.clear();
            planned_models.resize(w_count * crate::dfg::models::N_MODELS, 0);
        }
        let mut adfg = Adfg::unassigned(n);

        // lint: hot-path
        // The Algorithm 1 planning loop runs for every job against every
        // worker; PlanScratch exists precisely so this region allocates
        // nothing (PR 2).
        // Lines 4-12: descending rank order (precomputed statically, §4.2.1).
        for &t in dfg.rank_order() {
            probe.begin(t);
            // Hoist the worker-invariant fetch cost (Eq. 2 second arm) out
            // of the O(W) inner loop.
            let model = dfg.vertices[t].model;
            let fetch_cost = model.map(|m| view.cost.td_model(model_bytes(m))).unwrap_or(0);
            let mut best_w = 0;
            let mut best_ft = Micros::MAX;
            for w in 0..w_count {
                // Dead workers are masked out before any finish-time
                // arithmetic (their rows hold the POISONED_FT sentinel).
                if !view.alive(w) {
                    continue;
                }
                // AT_allInputs(t, w) — Eqs. 3-4. Predecessors are already
                // assigned (rank order is topological within a job).
                let at_inputs = if dfg.preds[t].is_empty() {
                    // Entry task: client input sits on the ingress worker.
                    view.now + view.cost.td_input(job.input_bytes, view.self_worker, w)
                } else {
                    dfg.preds[t]
                        .iter()
                        .map(|&p| {
                            let pw = adfg.get(p).expect("pred assigned before succ");
                            task_ft[p]
                                + view.cost.td_input(dfg.vertices[p].output_bytes, pw, w)
                        })
                        .max()
                        .unwrap()
                };
                // Line 8: x ← max(worker_FT_map[w], AT_allInputs(t, w)).
                let x = worker_ft[w].max(at_inputs);
                // Line 9: FT(t,w) ← x + TD_model + R(t, w). Under batching,
                // a task placed where this plan already put same-model work
                // would coalesce with it: the model is (being) fetched there
                // already, and a member joining an open batch pays only the
                // (1-alpha) marginal pass instead of a full runtime.
                let (td_model, r_us) = match model {
                    Some(m) => {
                        let base_r = view.r(dfg, t, w);
                        if batching {
                            let cnt =
                                planned_models[w * crate::dfg::models::N_MODELS + m as usize];
                            let td = if cnt > 0 {
                                0
                            } else {
                                self.td_model_arms(m, fetch_cost, w, view)
                            };
                            let r = if cnt % batch.batch_max as u32 != 0 {
                                let alpha =
                                    batch.alpha(crate::dfg::models::batch_alpha(m));
                                ((1.0 - alpha) * base_r as f64) as Micros
                            } else {
                                base_r
                            };
                            (td, r)
                        } else {
                            (self.td_model_arms(m, fetch_cost, w, view), base_r)
                        }
                    }
                    None => (0, view.r(dfg, t, w)),
                };
                let ft = x + td_model + r_us;
                probe.offer(w, ft);
                if ft < best_ft {
                    best_ft = ft;
                    best_w = w;
                }
            }
            // Lines 10-12.
            adfg.set(t, best_w);
            task_ft[t] = best_ft;
            worker_ft[best_w] = best_ft;
            if batching {
                if let Some(m) = dfg.vertices[t].model {
                    planned_models[best_w * crate::dfg::models::N_MODELS + m as usize] += 1;
                }
            }
        }
        // lint: end-hot-path
        adfg
    }

    /// Algorithm 2 — Task Dynamic Adjustment. Called when `ctx.task` becomes
    /// dispatchable on the worker that finished its (last) predecessor.
    fn assign_probed(
        &self,
        ctx: &AssignCtx,
        view: &ClusterView,
        probe: &mut DecisionProbe,
    ) -> WorkerId {
        let planned = ctx.planned.expect("compass plans every task");
        // A dead planned worker forces a re-placement regardless of the
        // ablation switches, the join pin, or the wait threshold — the
        // recovery path (DESIGN.md §9) depends on this override.
        let planned_dead = !view.alive(planned);
        if !planned_dead {
            if !self.cfg.dynamic_adjust {
                probe.offer(planned, 0);
                return planned;
            }
            // Line 3: join tasks cannot be moved without predecessor
            // coordination.
            if ctx.dfg.is_join(ctx.task) {
                probe.offer(planned, 0);
                return planned;
            }
            // Line 2: FT(w) > R(t, w) * threshold ⇒ reschedule.
            let r_planned = view.r(ctx.dfg, ctx.task, planned);
            let above =
                view.wait(planned) as f64 > r_planned as f64 * self.cfg.adjust_threshold;
            if !above {
                probe.offer(planned, view.wait(planned));
                return planned;
            }
        }
        // Lines 6-12: rank workers by earliest finish for this task. All
        // inputs already exist (t just became dispatchable), so they are
        // available `now` at their holders.
        // lint: hot-path
        // Algorithm 2 runs on every task dispatch; like planning, it must
        // not allocate per decision.
        let mut best = view.fallback_alive(planned);
        let mut best_ft = Micros::MAX;
        for w in 0..view.n_workers() {
            if !view.alive(w) {
                continue;
            }
            // Lines 8-11: queue wait + model fetch + runtime, plus the input
            // transfer when moving off this scheduler's worker (arrival_at
            // charges only non-colocated inputs, a refinement of line 11).
            let arrive = arrival_at(view, ctx.pred_outputs, view.now, w);
            let start = view.ft(w).max(arrive);
            let ft = start
                + self.td_model_est(ctx.dfg, ctx.task, w, view)
                + view.r(ctx.dfg, ctx.task, w);
            probe.offer(w, ft);
            if ft < best_ft {
                best_ft = ft;
                best = w;
            }
        }
        // lint: end-hot-path
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CompassConfig;
    use crate::core::{GB, MS, SEC};
    use crate::dfg::models::OPT;
    use crate::dfg::pipelines;
    use crate::net::CostModel;
    use crate::sst::SstRow;

    use crate::sched::PlanCell;

    fn view_with<'a>(
        rows: &'a [SstRow],
        cost: &'a CostModel,
        speed: &'a [f64],
        scratch: &'a PlanCell,
    ) -> ClusterView<'a> {
        ClusterView { now: 0, self_worker: 0, rows, cost, speed, scratch }
    }

    fn job(kind: crate::dfg::PipelineKind) -> Job {
        Job { id: 1, kind, arrival_us: 0, input_bytes: 1000 }
    }

    #[test]
    fn plan_assigns_every_task() {
        let cost = CostModel::default();
        let dfg = pipelines::translation(&cost);
        let rows = vec![SstRow::default(); 5];
        let speed = vec![1.0; 5];
        let c = Compass::new(CompassConfig::default());
        let adfg = c.plan(&job(dfg.kind), &dfg, &view_with(&rows, &cost, &speed, &PlanCell::default()));
        assert!(adfg.assignment.iter().all(|a| a.is_some()));
    }

    #[test]
    fn plan_prefers_cached_model_worker() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost); // v0 needs OPT
        let mut rows = vec![SstRow::default(); 3];
        for r in rows.iter_mut() {
            r.free_cache_bytes = 16 * GB;
        }
        rows[2].cache_bitmap = 1 << OPT; // only worker 2 has OPT resident
        let speed = vec![1.0; 3];
        let c = Compass::new(CompassConfig::default());
        let adfg = c.plan(&job(dfg.kind), &dfg, &view_with(&rows, &cost, &speed, &PlanCell::default()));
        assert_eq!(adfg.get(0), Some(2), "should chase the cached OPT");
    }

    #[test]
    fn locality_ablation_ignores_cache() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let mut rows = vec![SstRow::default(); 3];
        for r in rows.iter_mut() {
            r.free_cache_bytes = 16 * GB;
        }
        rows[2].cache_bitmap = 1 << OPT;
        let speed = vec![1.0; 3];
        let c = Compass::new(CompassConfig { model_locality: false, ..Default::default() });
        let adfg = c.plan(&job(dfg.kind), &dfg, &view_with(&rows, &cost, &speed, &PlanCell::default()));
        // Without locality the estimate is uniform; ingress colocation wins.
        assert_eq!(adfg.get(0), Some(0));
    }

    #[test]
    fn plan_balances_away_from_loaded_worker() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let mut rows = vec![SstRow::default(); 2];
        rows[0].ft_us = 60 * SEC; // worker 0 has a huge backlog
        for r in rows.iter_mut() {
            r.free_cache_bytes = 16 * GB;
        }
        let speed = vec![1.0; 2];
        let c = Compass::new(CompassConfig::default());
        let adfg = c.plan(&job(dfg.kind), &dfg, &view_with(&rows, &cost, &speed, &PlanCell::default()));
        assert!(adfg.assignment.iter().all(|&a| a == Some(1)));
    }

    #[test]
    fn eviction_penalty_steers_to_free_worker() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let mut rows = vec![SstRow::default(); 2];
        rows[0].free_cache_bytes = 0; // would need eviction
        rows[1].free_cache_bytes = 16 * GB;
        let speed = vec![1.0; 2];
        let c = Compass::new(CompassConfig::default());
        let adfg = c.plan(&job(dfg.kind), &dfg, &view_with(&rows, &cost, &speed, &PlanCell::default()));
        assert_eq!(adfg.get(0), Some(1));
    }

    #[test]
    fn adjust_keeps_plan_when_wait_low() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let rows = vec![SstRow::default(); 3];
        let speed = vec![1.0; 3];
        let scratch = PlanCell::default();
        let view = view_with(&rows, &cost, &speed, &scratch);
        let c = Compass::new(CompassConfig::default());
        let j = job(dfg.kind);
        let outs = [(0usize, 100u64)];
        let ctx = AssignCtx { job: &j, dfg: &dfg, task: 1, planned: Some(1), pred_outputs: &outs };
        assert_eq!(c.assign(&ctx, &view), 1);
    }

    #[test]
    fn adjust_moves_overloaded_nonjoin() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let mut rows = vec![SstRow::default(); 3];
        rows[1].ft_us = 120 * SEC; // planned worker overloaded
        for r in rows.iter_mut() {
            r.free_cache_bytes = 16 * GB;
        }
        let speed = vec![1.0; 3];
        let view = ClusterView {
            now: 10 * MS,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &PlanCell::default(),
        };
        let c = Compass::new(CompassConfig::default());
        let j = job(dfg.kind);
        let outs = [(0usize, 100u64)];
        let ctx = AssignCtx { job: &j, dfg: &dfg, task: 1, planned: Some(1), pred_outputs: &outs };
        let w = c.assign(&ctx, &view);
        assert_ne!(w, 1, "should escape the overloaded worker");
    }

    #[test]
    fn adjust_never_moves_join() {
        let cost = CostModel::default();
        let dfg = pipelines::perception(&cost);
        let mut rows = vec![SstRow::default(); 3];
        rows[2].ft_us = 120 * SEC;
        let speed = vec![1.0; 3];
        let scratch = PlanCell::default();
        let view = view_with(&rows, &cost, &speed, &scratch);
        let c = Compass::new(CompassConfig::default());
        let j = job(dfg.kind);
        let outs = [(0usize, 100u64), (1usize, 100u64)];
        let ctx =
            AssignCtx { job: &j, dfg: &dfg, task: dfg.exit, planned: Some(2), pred_outputs: &outs };
        assert_eq!(c.assign(&ctx, &view), 2, "join tasks are pinned");
    }

    /// Fan-out DFG whose two middle tasks share one model: 0 → {1, 2} → 3.
    fn same_model_fanout(cost: &CostModel) -> crate::dfg::Dfg {
        use crate::dfg::{Dfg, PipelineKind, Vertex};
        let v = |id, model, rt| Vertex {
            id,
            name: "t",
            model,
            mean_runtime_us: rt,
            output_bytes: 1000,
        };
        Dfg::new(
            PipelineKind::Vpa,
            vec![
                v(0, None, MS),
                v(1, Some(OPT), 100 * MS),
                v(2, Some(OPT), 100 * MS),
                v(3, None, MS),
            ],
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            cost,
        )
    }

    /// The score Algorithm 1 offered for worker `w` while planning `task`.
    fn offered(recs: &[(usize, crate::obs::CandidateSet)], task: usize, w: u16) -> Micros {
        recs.iter()
            .find(|(t, _)| *t == task)
            .and_then(|(_, c)| c.iter().find(|&(cw, _)| cw == w))
            .map(|(_, s)| s)
            .expect("candidate recorded")
    }

    #[test]
    fn batching_discounts_same_model_followup() {
        let mut cost = CostModel::default();
        let dfg = same_model_fanout(&cost);
        let mut rows = vec![SstRow::default(); 3];
        for r in rows.iter_mut() {
            r.free_cache_bytes = 16 * GB;
        }
        let speed = vec![1.0; 3];
        let c = Compass::new(CompassConfig::default());
        let probe_plan = |cost: &CostModel| {
            let view = view_with(&rows, cost, &speed, &PlanCell::default());
            let mut probe = crate::sched::DecisionProbe::on();
            let adfg = c.plan_probed(&job(dfg.kind), &dfg, &view, &mut probe);
            (adfg, probe.take_records())
        };
        let (off_adfg, off_recs) = probe_plan(&cost);
        cost.batch.batch_max = 4;
        cost.batch.alpha_override = Some(0.5);
        let (on_adfg, on_recs) = probe_plan(&cost);
        assert!(on_adfg.assignment.iter().all(|a| a.is_some()));
        // Task 1 plans before any same-model placement: scores unchanged.
        let w1 = off_adfg.get(1).unwrap() as u16;
        assert_eq!(offered(&off_recs, 1, w1), offered(&on_recs, 1, w1));
        // Task 2 on task 1's worker joins the plan's open batch: no second
        // model fetch and only the (1-alpha) marginal pass.
        let score_off = offered(&off_recs, 2, w1);
        let score_on = offered(&on_recs, 2, w1);
        assert!(
            score_on < score_off,
            "batching must discount a same-model follow-up: on={score_on} off={score_off}"
        );
        let fetch = cost.td_model(crate::dfg::models::model_bytes(OPT));
        assert!(score_off - score_on >= fetch + 50 * MS / 2, "fetch + alpha·R discount");
    }

    #[test]
    fn batch_max_one_plans_identically() {
        let mut cost = CostModel::default();
        cost.batch.window_us = 777;
        cost.batch.alpha_override = Some(0.2);
        // batch_max stays 1: every estimate must match the default plan.
        let dfg = same_model_fanout(&CostModel::default());
        let rows = vec![SstRow::default(); 3];
        let speed = vec![1.0; 3];
        let c = Compass::new(CompassConfig::default());
        let base = c.plan(
            &job(dfg.kind),
            &dfg,
            &view_with(&rows, &CostModel::default(), &speed, &PlanCell::default()),
        );
        let mut probe = crate::sched::DecisionProbe::on();
        let tweaked = c.plan_probed(
            &job(dfg.kind),
            &dfg,
            &view_with(&rows, &cost, &speed, &PlanCell::default()),
            &mut probe,
        );
        assert_eq!(base.assignment, tweaked.assignment);
    }

    #[test]
    fn adjust_disabled_is_identity() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let mut rows = vec![SstRow::default(); 3];
        rows[1].ft_us = 120 * SEC;
        let speed = vec![1.0; 3];
        let scratch = PlanCell::default();
        let view = view_with(&rows, &cost, &speed, &scratch);
        let c = Compass::new(CompassConfig { dynamic_adjust: false, ..Default::default() });
        let j = job(dfg.kind);
        let outs = [(0usize, 100u64)];
        let ctx = AssignCtx { job: &j, dfg: &dfg, task: 1, planned: Some(1), pred_outputs: &outs };
        assert_eq!(c.assign(&ctx, &view), 1);
    }
}
