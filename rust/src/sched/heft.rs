//! Classic HEFT baseline (§6.2.1).
//!
//! Upward-rank ordering and earliest-finish-time worker selection
//! (Topcuoglu et al. 2002), but — as the paper emphasizes — *without* the
//! Compass extensions: no worker queue load (FT(w) from the SST is
//! ignored), no ML-model locality, and no dynamic adjustment (the ADFG is
//! locked at planning time). Within one job instance it still tracks its
//! own processor-availability map, as classic HEFT does.

use super::{AssignCtx, ClusterView, DecisionProbe, PlanScratch, Scheduler};
use crate::config::SchedulerKind;
use crate::core::{Micros, WorkerId};
use crate::dfg::{Adfg, Dfg, Job};

pub struct Heft;

impl Scheduler for Heft {
    fn kind(&self) -> SchedulerKind {
        SchedulerKind::Heft
    }

    fn plan_probed(
        &self,
        job: &Job,
        dfg: &Dfg,
        view: &ClusterView,
        probe: &mut DecisionProbe,
    ) -> Adfg {
        let n = dfg.len();
        let w_count = view.n_workers();
        // Per-job processor availability; starts at `now` everywhere —
        // the cluster-wide backlog is invisible to classic HEFT. The
        // caller-owned scratch's worker_ft doubles as the availability map,
        // so planning allocates nothing per job beyond the returned ADFG.
        let mut scratch = view.scratch.borrow_mut();
        let PlanScratch { worker_ft: avail, task_ft, .. } = &mut *scratch;
        avail.clear();
        avail.resize(w_count, view.now);
        task_ft.clear();
        task_ft.resize(n, 0);
        let mut adfg = Adfg::unassigned(n);

        // lint: hot-path
        // HEFT's planning loop shares PlanScratch with Algorithm 1 and the
        // same allocation budget: none.
        for &t in dfg.rank_order() {
            probe.begin(t);
            let mut best_w = 0;
            let mut best_ft = Micros::MAX;
            for w in 0..w_count {
                // Classic HEFT ignores the SST's load data, but liveness
                // still comes from it: dead workers are masked out.
                if !view.alive(w) {
                    continue;
                }
                let at_inputs = if dfg.preds[t].is_empty() {
                    view.now + view.cost.td_input(job.input_bytes, view.self_worker, w)
                } else {
                    dfg.preds[t]
                        .iter()
                        .map(|&p| {
                            let pw = adfg.get(p).unwrap();
                            task_ft[p] + view.cost.td_input(dfg.vertices[p].output_bytes, pw, w)
                        })
                        .max()
                        .unwrap()
                };
                let eft = avail[w].max(at_inputs) + view.r(dfg, t, w);
                probe.offer(w, eft);
                if eft < best_ft {
                    best_ft = eft;
                    best_w = w;
                }
            }
            adfg.set(t, best_w);
            task_ft[t] = best_ft;
            avail[best_w] = best_ft;
        }
        // lint: end-hot-path
        adfg
    }

    /// No adjustment phase: workers adhere to the locked schedule. The one
    /// exception is liveness — a schedule locked onto a worker that has
    /// since died falls back to the next alive peer on the ring.
    fn assign_probed(
        &self,
        ctx: &AssignCtx,
        view: &ClusterView,
        probe: &mut DecisionProbe,
    ) -> WorkerId {
        let planned = view.fallback_alive(ctx.planned.expect("HEFT plans every task"));
        probe.offer(planned, 0);
        planned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::SEC;
    use crate::dfg::pipelines;
    use crate::net::CostModel;
    use crate::sst::SstRow;

    #[test]
    fn plan_ignores_queue_backlog() {
        // Worker 0 is hugely backlogged in the SST, but classic HEFT cannot
        // see it — with symmetric workers it still lands tasks there.
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let mut rows = vec![SstRow::default(); 2];
        rows[0].ft_us = 600 * SEC;
        let speed = vec![1.0; 2];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &crate::sched::PlanCell::default(),
        };
        let job = Job { id: 1, kind: dfg.kind, arrival_us: 0, input_bytes: 1000 };
        let adfg = Heft.plan(&job, &dfg, &view);
        // Chain pipeline colocates on the ingress worker: exactly the
        // blindness the paper criticizes.
        assert_eq!(adfg.get(0), Some(0));
    }

    #[test]
    fn parallel_branches_spread_across_workers() {
        let cost = CostModel::default();
        let dfg = pipelines::translation(&cost);
        let rows = vec![SstRow::default(); 4];
        let speed = vec![1.0; 4];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &crate::sched::PlanCell::default(),
        };
        let job = Job { id: 1, kind: dfg.kind, arrival_us: 0, input_bytes: 1000 };
        let adfg = Heft.plan(&job, &dfg, &view);
        // The three translation branches (tasks 1..3) must not all share one
        // worker: HEFT's EFT criterion exploits parallelism.
        let ws: std::collections::HashSet<_> =
            [1, 2, 3].iter().map(|&t| adfg.get(t).unwrap()).collect();
        assert!(ws.len() >= 2, "branches collapsed onto {ws:?}");
    }

    #[test]
    fn assign_is_locked_to_plan() {
        let cost = CostModel::default();
        let dfg = pipelines::vpa(&cost);
        let rows = vec![SstRow::default(); 2];
        let speed = vec![1.0; 2];
        let view = ClusterView {
            now: 0,
            self_worker: 0,
            rows: &rows,
            cost: &cost,
            speed: &speed,
            scratch: &crate::sched::PlanCell::default(),
        };
        let job = Job { id: 1, kind: dfg.kind, arrival_us: 0, input_bytes: 1000 };
        let outs = [(0usize, 10u64)];
        let ctx = AssignCtx { job: &job, dfg: &dfg, task: 1, planned: Some(1), pred_outputs: &outs };
        assert_eq!(Heft.assign(&ctx, &view), 1);
    }
}
