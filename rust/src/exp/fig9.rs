//! Figure 9 — production-trace replay (§6.4).
//!
//! The paper replays an Alibaba production-GPU-cluster trace rescaled to
//! its 5-worker testbed. We substitute a synthesized trace with the same
//! burst structure (see `workload::alibaba_like` and DESIGN.md §3) and
//! replay it under all four schedulers. Shape to reproduce: Hash is least
//! burst-tolerant; Compass keeps the best completion times through the
//! bursts.

use super::{Runner, Scale};
use crate::config::{ClusterConfig, SchedulerKind};
use crate::util::stats::percentile;
use crate::util::table;
use crate::workload;
use crate::Simulator;

#[derive(Debug, Clone)]
pub struct TraceRow {
    pub scheduler: SchedulerKind,
    pub p50_s: f64,
    pub p95_s: f64,
    pub max_s: f64,
    pub mean_slowdown: f64,
}

pub struct TraceResult {
    pub rows: Vec<TraceRow>,
    pub bucket_rates: Vec<f64>,
}

pub fn compute(scale: Scale) -> TraceResult {
    compute_with(&Runner::from_env(), scale)
}

/// One trace replay per scheduler, all sharing the same synthesized job
/// stream (borrowed, not cloned, into each run).
pub fn compute_with(runner: &Runner, scale: Scale) -> TraceResult {
    let duration_s = (scale.jobs as f64 / 2.0).max(60.0);
    let (jobs, buckets) = workload::alibaba_like(2.0, duration_s, scale.seed ^ 0xa11b);
    let rows = runner.par_map(&SchedulerKind::ALL, |_, &s| {
        let cfg = ClusterConfig::default().with_scheduler(s).with_seed(scale.seed);
        let m = Simulator::simulate_ref(&cfg, &jobs).metrics;
        let lats: Vec<f64> = m.jobs.iter().map(|j| j.latency_us() as f64 / 1e6).collect();
        TraceRow {
            scheduler: s,
            p50_s: percentile(&lats, 50.0),
            p95_s: percentile(&lats, 95.0),
            max_s: percentile(&lats, 100.0),
            mean_slowdown: m.mean_slowdown(),
        }
    });
    TraceResult { rows, bucket_rates: buckets.iter().map(|b| b.rate_per_s).collect() }
}

pub fn run(scale: Scale) -> TraceResult {
    let r = compute(scale);
    println!("\n=== Figure 9 — production-trace replay (bursty arrivals) ===");
    let peak = r.bucket_rates.iter().cloned().fold(0.0, f64::max);
    let mean = r.bucket_rates.iter().sum::<f64>() / r.bucket_rates.len() as f64;
    println!(
        "trace: {} buckets, mean {:.1} req/s, peak {:.1} req/s (burst factor {:.1}x)\n",
        r.bucket_rates.len(),
        mean,
        peak,
        peak / mean
    );
    let body: Vec<Vec<String>> = r
        .rows
        .iter()
        .map(|row| {
            vec![
                row.scheduler.name().to_string(),
                format!("{:.2}", row.p50_s),
                format!("{:.2}", row.p95_s),
                format!("{:.2}", row.max_s),
                format!("{:.2}", row.mean_slowdown),
            ]
        })
        .collect();
    print!(
        "{}",
        table::render(
            &["scheduler", "p50 latency (s)", "p95 latency (s)", "max (s)", "mean slowdown"],
            &body
        )
    );
    r
}
