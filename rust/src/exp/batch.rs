//! Batching sweep — worker execute-path coalescing under same-model load.
//!
//! Runs the Compass scheduler on an all-VPA workload (every job funnels
//! through the same OPT → BART model pair, so same-model queue-mates are
//! common) at a rate that builds real queues, sweeping `batch_max` over
//! {1, 2, 4, 8}. The expected shape: `batch_max = 1` is the unbatched
//! baseline; larger batches amortize the activation pass per the sublinear
//! cost curve `alpha·max + (1-alpha)·sum`, draining queues faster and
//! cutting mean latency until the window-hold cost catches up.

use super::{Runner, Scale};
use crate::config::{ClusterConfig, SchedulerKind};
use crate::core::Micros;
use crate::metrics::MetricsSink;
use crate::util::table;
use crate::workload;
use crate::Simulator;

/// Request rate for the sweep: high enough that queues form on the five
/// default workers, the regime batching exists for.
const SWEEP_RATE: f64 = 4.0;
/// Batching window used for every enabled cell, µs.
const SWEEP_WINDOW_US: Micros = 1_000;

/// Structured result: one row per swept `batch_max`.
pub struct BatchSweepResult {
    pub batch_maxes: Vec<usize>,
    pub mean_latency_s: Vec<f64>,
    pub mean_slowdown: Vec<f64>,
    pub median_slowdown: Vec<f64>,
}

impl BatchSweepResult {
    pub fn mean_latency_at(&self, batch_max: usize) -> f64 {
        let i = self.batch_maxes.iter().position(|&b| b == batch_max).expect("swept batch_max");
        self.mean_latency_s[i]
    }
}

fn scenario(batch_max: usize, scale: Scale) -> MetricsSink {
    let cfg = ClusterConfig::default()
        .with_scheduler(SchedulerKind::Compass)
        .with_seed(scale.seed)
        .with_batching(batch_max, SWEEP_WINDOW_US);
    // Same-model-heavy stream: VPA-only mix, shared across all cells.
    let jobs = workload::poisson(
        SWEEP_RATE,
        scale.jobs,
        &[0.0, 0.0, 1.0, 0.0],
        scale.seed ^ 0x9e37_79b9,
    );
    Simulator::simulate(cfg, jobs).metrics
}

/// Every cell is an independent run: fan them across the runner's pool.
/// Results come back in sweep order, so the printed table is stable.
pub fn compute_sweep(runner: &Runner, scale: Scale) -> BatchSweepResult {
    let batch_maxes = vec![1usize, 2, 4, 8];
    let cells = runner.par_map(&batch_maxes, |_, &b| {
        let m = scenario(b, scale);
        (m.mean_latency_s(), m.mean_slowdown(), m.median_slowdown())
    });
    BatchSweepResult {
        batch_maxes,
        mean_latency_s: cells.iter().map(|c| c.0).collect(),
        mean_slowdown: cells.iter().map(|c| c.1).collect(),
        median_slowdown: cells.iter().map(|c| c.2).collect(),
    }
}

pub fn run(scale: Scale) -> BatchSweepResult {
    let result = compute_sweep(&Runner::from_env(), scale);

    println!("\n=== Batching sweep — VPA-only load, {SWEEP_RATE} req/s, compass ===\n");
    let mut rows = Vec::new();
    for (i, &b) in result.batch_maxes.iter().enumerate() {
        rows.push(vec![
            format!("{b}"),
            format!("{:.3}", result.mean_latency_s[i]),
            format!("{:.2}", result.mean_slowdown[i]),
            format!("{:.2}", result.median_slowdown[i]),
        ]);
    }
    print!(
        "{}",
        table::render(&["batch_max", "mean latency s", "mean slowdown", "median slowdown"], &rows)
    );
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shows_batching_wins_under_same_model_load() {
        let scale = Scale { jobs: 80, seed: 17 };
        let r = compute_sweep(&Runner::serial(), scale);
        assert_eq!(r.batch_maxes, vec![1, 2, 4, 8]);
        assert!(r.mean_latency_s.iter().all(|&l| l > 0.0));
        assert!(
            r.mean_latency_at(8) < r.mean_latency_at(1),
            "batch_max 8 must beat unbatched: {:?}",
            r.mean_latency_s
        );
    }
}
