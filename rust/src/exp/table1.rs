//! Table 1 — scheduler performance metrics under the Fig. 6b scenario
//! (2 req/s): mean latency, GPU utilization, GPU memory utilization, GPU
//! energy, GPU cache hit rate.
//!
//! Shape to reproduce: all schedulers consume similar GPU resources and
//! energy, but Compass's latency is lowest by a wide margin and its cache
//! hit rate is the highest (paper: 99% vs 91–95%).

use super::{run_scenario, Runner, Scale};
use crate::config::SchedulerKind;
use crate::util::table;

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub scheduler: SchedulerKind,
    pub latency_s: f64,
    pub gpu_util_pct: f64,
    pub mem_util_pct: f64,
    pub energy_j: f64,
    pub hit_rate_pct: f64,
}

pub fn compute(scale: Scale) -> Vec<Table1Row> {
    compute_with(&Runner::from_env(), scale)
}

pub fn compute_with(runner: &Runner, scale: Scale) -> Vec<Table1Row> {
    runner.par_map(&SchedulerKind::ALL, |_, &s| {
        let m = run_scenario(s, 2.0, scale, |_| {});
        Table1Row {
            scheduler: s,
            latency_s: m.mean_latency_s(),
            gpu_util_pct: m.gpu_utilization(),
            mem_util_pct: m.gpu_memory_utilization(),
            energy_j: m.gpu_energy_joules(),
            hit_rate_pct: m.cache_hit_rate(),
        }
    })
}

pub fn run(scale: Scale) -> Vec<Table1Row> {
    let rows = compute(scale);
    println!("\n=== Table 1 — scheduler performance metrics (2 req/s) ===\n");
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.scheduler.name().to_string(),
                format!("{:.1}", r.latency_s),
                format!("{:.0}", r.gpu_util_pct),
                format!("{:.0}", r.mem_util_pct),
                format!("{:.0}", r.energy_j),
                format!("{:.1}", r.hit_rate_pct),
            ]
        })
        .collect();
    print!(
        "{}",
        table::render(
            &["scheduler", "latency (s)", "gpu util %", "mem util %", "energy (J)", "hit rate %"],
            &body
        )
    );
    rows
}
