//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§6). Each submodule prints the same rows/series the paper
//! reports and returns structured results the benches and tests consume.
//!
//! | module   | paper artifact                                      |
//! |----------|-----------------------------------------------------|
//! | fig6     | Fig. 6a/6b (slowdown box plots), Fig. 6c (rate sweep)|
//! | table1   | Table 1 (latency / GPU metrics per scheduler)        |
//! | fig7     | Fig. 7 (ablation analysis)                           |
//! | fig8     | Fig. 8 (SST staleness sensitivity heatmap)           |
//! | fig9     | Fig. 9 (production-trace replay)                     |
//! | fig10    | Fig. 10 (scalability: Compass vs Hash, 5..250 workers)|
//! | batch    | execute-path batching sweep (batch_max 1..8)         |
//! | chaos    | crash-rate sweep: completion/p99 under fault injection|
//! | validate | §5.4 simulator-vs-live validation                    |

pub mod batch;
pub mod chaos;
pub mod fig10;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod runner;
pub mod table1;
pub mod validate;

pub use runner::Runner;

use crate::config::{ClusterConfig, SchedulerKind};
use crate::metrics::MetricsSink;
use crate::util::args::Args;
use crate::workload;
use crate::Simulator;

/// Scale knobs shared by all experiments. `--quick` shrinks workloads for
/// CI/bench runs; full size matches the statistical weight of the paper.
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub jobs: usize,
    pub seed: u64,
}

impl Scale {
    pub fn from_args(args: &Args) -> Scale {
        let quick = args.flag("quick");
        Scale {
            jobs: args.get_usize("jobs", if quick { 150 } else { 600 }),
            seed: args.get_u64("seed", 42),
        }
    }

    pub fn quick() -> Scale {
        Scale { jobs: 150, seed: 42 }
    }
}

/// Run one simulator scenario: `scheduler` at `rate` req/s over the
/// standard 4-pipeline mix. Returns the full report (incl. the trace when
/// the mutator enabled it).
pub fn run_scenario_report(
    scheduler: SchedulerKind,
    rate: f64,
    scale: Scale,
    mutate: impl FnOnce(&mut ClusterConfig),
) -> crate::sim::SimReport {
    let mut cfg = ClusterConfig::default().with_scheduler(scheduler).with_seed(scale.seed);
    mutate(&mut cfg);
    // Workload seed is shared across schedulers: identical request streams.
    let jobs = workload::poisson(rate, scale.jobs, &[], scale.seed ^ 0x9e37_79b9);
    Simulator::simulate(cfg, jobs)
}

/// Metrics-only variant of [`run_scenario_report`] — what most experiment
/// modules consume.
pub fn run_scenario(
    scheduler: SchedulerKind,
    rate: f64,
    scale: Scale,
    mutate: impl FnOnce(&mut ClusterConfig),
) -> MetricsSink {
    run_scenario_report(scheduler, rate, scale, mutate).metrics
}

/// CLI dispatch for `compass experiment <which>`.
pub fn run(which: &str, args: &Args) -> anyhow::Result<()> {
    let scale = Scale::from_args(args);
    // `--threads N` pins the experiment runner's parallelism (also settable
    // via COMPASS_THREADS). Results are byte-identical at any thread count;
    // this only trades wall-clock for cores.
    if let Some(t) = args.get("threads") {
        std::env::set_var(runner::THREADS_ENV, t);
    }
    match which {
        "fig6a" => {
            fig6::boxes(0.5, scale, "Figure 6a — low load (0.5 req/s)");
        }
        "fig6b" => {
            fig6::boxes(2.0, scale, "Figure 6b — high load (2 req/s)");
        }
        "fig6c" => {
            fig6::rate_sweep(scale);
        }
        "table1" => {
            table1::run(scale);
        }
        "fig7" => {
            fig7::run(scale);
        }
        "fig8" => {
            fig8::run(scale);
        }
        "fig9" => {
            fig9::run(scale);
        }
        "fig10" => {
            fig10::run(scale, args.flag("quick"));
        }
        "batch" => {
            batch::run(scale);
        }
        "chaos" => {
            chaos::run(scale);
        }
        "all" => {
            fig6::boxes(0.5, scale, "Figure 6a — low load (0.5 req/s)");
            fig6::boxes(2.0, scale, "Figure 6b — high load (2 req/s)");
            fig6::rate_sweep(scale);
            table1::run(scale);
            fig7::run(scale);
            fig8::run(scale);
            fig9::run(scale);
            fig10::run(scale, args.flag("quick"));
            batch::run(scale);
            chaos::run(scale);
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    }

    // Observability side-channel: with `--trace-out` / `--metrics-out`,
    // re-run the canonical Compass scenario (2 req/s, the Fig. 6b operating
    // point) with tracing on and export it. Experiments themselves stay
    // untraced so their numbers match the paper runs exactly.
    let trace_out = args.get_path("trace-out");
    let metrics_out = args.get_path("metrics-out");
    if trace_out.is_some() || metrics_out.is_some() {
        let rep = run_scenario_report(SchedulerKind::Compass, 2.0, scale, |cfg| {
            cfg.trace.enabled = true;
        });
        crate::obs::write_outputs(
            &rep.trace,
            &rep.metrics,
            trace_out.as_deref(),
            metrics_out.as_deref(),
        )?;
        if let Some(p) = &trace_out {
            println!(
                "chrome trace ({} events) written to {}",
                rep.trace.events.len(),
                p.display()
            );
        }
        if let Some(p) = &metrics_out {
            println!("metrics snapshot written to {}", p.display());
        }
    }
    Ok(())
}

/// `compass validate` CLI (§5.4 sim-vs-live comparison).
pub fn validate_cli(args: &Args) -> anyhow::Result<()> {
    let n_jobs = args.get_usize("jobs", 40);
    let artifacts = args.get("artifacts").map(std::path::PathBuf::from);
    let r = validate::run(n_jobs, args.get_u64("seed", 42), artifacts)?;
    println!("{}", r.render());
    if r.within_tolerance(0.15) {
        println!("VALIDATION OK: sim and live medians within 15%");
    } else {
        println!("VALIDATION DIVERGED (>{:.0}%)", 15.0);
    }
    Ok(())
}
