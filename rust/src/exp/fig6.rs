//! Figure 6 — scheduler comparison.
//!
//! 6a/6b: per-pipeline slow-down-factor box plots under steady low
//! (0.5 req/s) and high (2 req/s) Poisson load, for Compass vs JIT vs HEFT
//! vs Hash. 6c: mean slow-down vs request rate. The paper's shape to
//! reproduce: everyone is near-optimal at low load with Compass closest to
//! 1.0; at high load Compass wins clearly, JIT second, HEFT worst; the
//! short pipelines (image caption, 3D perception) blow up the most for the
//! losing schedulers.

use super::{run_scenario, Runner, Scale};
use crate::config::SchedulerKind;
use crate::dfg::PipelineKind;
use crate::util::stats::BoxStats;
use crate::util::table;

/// Structured result: per (scheduler, pipeline) box stats.
pub struct BoxesResult {
    pub rate: f64,
    pub per_sched: Vec<(SchedulerKind, Vec<(PipelineKind, BoxStats)>)>,
}

impl BoxesResult {
    pub fn stats(&self, s: SchedulerKind, k: PipelineKind) -> &BoxStats {
        &self
            .per_sched
            .iter()
            .find(|(x, _)| *x == s)
            .unwrap()
            .1
            .iter()
            .find(|(x, _)| *x == k)
            .unwrap()
            .1
    }

    pub fn median_overall(&self, s: SchedulerKind) -> f64 {
        let v: Vec<f64> = self
            .per_sched
            .iter()
            .find(|(x, _)| *x == s)
            .unwrap()
            .1
            .iter()
            .map(|(_, b)| b.median)
            .collect();
        crate::util::stats::mean(&v)
    }
}

/// Fan the four scheduler runs across the runner's pool; results come back
/// in `SchedulerKind::ALL` order, so the table below is byte-identical to
/// the old serial loop.
pub fn compute_boxes(runner: &Runner, rate: f64, scale: Scale) -> BoxesResult {
    let per_sched = runner.par_map(&SchedulerKind::ALL, |_, &s| {
        let m = run_scenario(s, rate, scale, |_| {});
        let per_kind: Vec<(PipelineKind, BoxStats)> = PipelineKind::ALL
            .iter()
            .filter_map(|&k| m.box_stats(k).map(|b| (k, b)))
            .collect();
        (s, per_kind)
    });
    BoxesResult { rate, per_sched }
}

pub fn boxes(rate: f64, scale: Scale, title: &str) -> BoxesResult {
    let result = compute_boxes(&Runner::from_env(), rate, scale);
    let per_sched = &result.per_sched;

    println!("\n=== {title} ===");
    println!("slow_down_factor distribution per job category (box plot stats)\n");
    let mut rows = Vec::new();
    for (s, per_kind) in per_sched {
        for (k, b) in per_kind {
            rows.push(vec![
                s.name().to_string(),
                k.name().to_string(),
                format!("{:.2}", b.q1),
                format!("{:.2}", b.median),
                format!("{:.2}", b.q3),
                format!("{:.2}", b.whisker_hi),
                format!("{}", b.outliers),
            ]);
        }
    }
    print!(
        "{}",
        table::render(&["scheduler", "pipeline", "q1", "median", "q3", "whisker-hi", "outliers"], &rows)
    );
    result
}

/// Figure 6c — mean slow-down factor vs request rate, mixed workload.
pub struct RateSweepResult {
    pub rates: Vec<f64>,
    /// means[scheduler_index][rate_index]
    pub means: Vec<Vec<f64>>,
}

impl RateSweepResult {
    pub fn mean(&self, s: SchedulerKind, rate_idx: usize) -> f64 {
        let si = SchedulerKind::ALL.iter().position(|&x| x == s).unwrap();
        self.means[si][rate_idx]
    }
}

/// All `scheduler × rate` cells are independent runs: flatten the grid so
/// the work-stealing pool balances the expensive high-rate cells, then
/// regroup per scheduler. Row/column order matches the serial nest.
pub fn compute_rate_sweep(runner: &Runner, scale: Scale) -> RateSweepResult {
    let rates = vec![0.5, 1.0, 1.5, 2.0, 2.5, 3.0];
    let cells: Vec<(SchedulerKind, f64)> = SchedulerKind::ALL
        .iter()
        .flat_map(|&s| rates.iter().map(move |&r| (s, r)))
        .collect();
    let flat =
        runner.par_map(&cells, |_, &(s, r)| run_scenario(s, r, scale, |_| {}).mean_slowdown());
    let means: Vec<Vec<f64>> = flat.chunks(rates.len()).map(|c| c.to_vec()).collect();
    RateSweepResult { rates, means }
}

pub fn rate_sweep(scale: Scale) -> RateSweepResult {
    let RateSweepResult { rates, means } = compute_rate_sweep(&Runner::from_env(), scale);

    println!("\n=== Figure 6c — mean slow-down factor vs request rate ===\n");
    let mut rows = Vec::new();
    for (si, s) in SchedulerKind::ALL.iter().enumerate() {
        let mut row = vec![s.name().to_string()];
        row.extend(means[si].iter().map(|m| format!("{m:.2}")));
        rows.push(row);
    }
    let mut headers: Vec<String> = vec!["scheduler".into()];
    headers.extend(rates.iter().map(|r| format!("{r} req/s")));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    print!("{}", table::render(&hdr_refs, &rows));
    RateSweepResult { rates, means }
}
