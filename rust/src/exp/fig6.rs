//! Figure 6 — scheduler comparison.
//!
//! 6a/6b: per-pipeline slow-down-factor box plots under steady low
//! (0.5 req/s) and high (2 req/s) Poisson load, for Compass vs JIT vs HEFT
//! vs Hash. 6c: mean slow-down vs request rate. The paper's shape to
//! reproduce: everyone is near-optimal at low load with Compass closest to
//! 1.0; at high load Compass wins clearly, JIT second, HEFT worst; the
//! short pipelines (image caption, 3D perception) blow up the most for the
//! losing schedulers.

use super::{run_scenario, Scale};
use crate::config::SchedulerKind;
use crate::dfg::PipelineKind;
use crate::util::stats::BoxStats;
use crate::util::table;

/// Structured result: per (scheduler, pipeline) box stats.
pub struct BoxesResult {
    pub rate: f64,
    pub per_sched: Vec<(SchedulerKind, Vec<(PipelineKind, BoxStats)>)>,
}

impl BoxesResult {
    pub fn stats(&self, s: SchedulerKind, k: PipelineKind) -> &BoxStats {
        &self
            .per_sched
            .iter()
            .find(|(x, _)| *x == s)
            .unwrap()
            .1
            .iter()
            .find(|(x, _)| *x == k)
            .unwrap()
            .1
    }

    pub fn median_overall(&self, s: SchedulerKind) -> f64 {
        let v: Vec<f64> = self
            .per_sched
            .iter()
            .find(|(x, _)| *x == s)
            .unwrap()
            .1
            .iter()
            .map(|(_, b)| b.median)
            .collect();
        crate::util::stats::mean(&v)
    }
}

pub fn boxes(rate: f64, scale: Scale, title: &str) -> BoxesResult {
    let mut per_sched = Vec::new();
    for s in SchedulerKind::ALL {
        let m = run_scenario(s, rate, scale, |_| {});
        let per_kind: Vec<(PipelineKind, BoxStats)> = PipelineKind::ALL
            .iter()
            .filter_map(|&k| m.box_stats(k).map(|b| (k, b)))
            .collect();
        per_sched.push((s, per_kind));
    }

    println!("\n=== {title} ===");
    println!("slow_down_factor distribution per job category (box plot stats)\n");
    let mut rows = Vec::new();
    for (s, per_kind) in &per_sched {
        for (k, b) in per_kind {
            rows.push(vec![
                s.name().to_string(),
                k.name().to_string(),
                format!("{:.2}", b.q1),
                format!("{:.2}", b.median),
                format!("{:.2}", b.q3),
                format!("{:.2}", b.whisker_hi),
                format!("{}", b.outliers),
            ]);
        }
    }
    print!(
        "{}",
        table::render(&["scheduler", "pipeline", "q1", "median", "q3", "whisker-hi", "outliers"], &rows)
    );
    BoxesResult { rate, per_sched }
}

/// Figure 6c — mean slow-down factor vs request rate, mixed workload.
pub struct RateSweepResult {
    pub rates: Vec<f64>,
    /// means[scheduler_index][rate_index]
    pub means: Vec<Vec<f64>>,
}

impl RateSweepResult {
    pub fn mean(&self, s: SchedulerKind, rate_idx: usize) -> f64 {
        let si = SchedulerKind::ALL.iter().position(|&x| x == s).unwrap();
        self.means[si][rate_idx]
    }
}

pub fn rate_sweep(scale: Scale) -> RateSweepResult {
    let rates = vec![0.5, 1.0, 1.5, 2.0, 2.5, 3.0];
    let mut means = Vec::new();
    for s in SchedulerKind::ALL {
        let mut row = Vec::new();
        for &r in &rates {
            let m = run_scenario(s, r, scale, |_| {});
            row.push(m.mean_slowdown());
        }
        means.push(row);
    }

    println!("\n=== Figure 6c — mean slow-down factor vs request rate ===\n");
    let mut rows = Vec::new();
    for (si, s) in SchedulerKind::ALL.iter().enumerate() {
        let mut row = vec![s.name().to_string()];
        row.extend(means[si].iter().map(|m| format!("{m:.2}")));
        rows.push(row);
    }
    let mut headers: Vec<String> = vec!["scheduler".into()];
    headers.extend(rates.iter().map(|r| format!("{r} req/s")));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    print!("{}", table::render(&hdr_refs, &rows));
    RateSweepResult { rates, means }
}
