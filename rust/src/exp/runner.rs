//! Parallel experiment engine: a std-only scoped-thread work-stealing
//! pool that fans independent simulation runs across cores.
//!
//! Every figure of §6 is a sweep of mutually independent `(config, seed,
//! workload)` simulator runs — each run is bit-reproducible from its own
//! seed, so the only thing parallelism could perturb is *aggregation
//! order*. [`Runner::par_map`] therefore writes each result into the slot
//! of its input index and returns them in input order: the merged output
//! is byte-identical to a serial loop, regardless of thread count or
//! completion order (locked by `tests/determinism_parallel.rs`).
//!
//! Work-stealing is a single shared atomic cursor: threads claim the next
//! unclaimed index as they finish, so uneven run lengths (a 250-worker
//! Fig. 10 point vs. a 5-worker one) self-balance without any up-front
//! partitioning.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Environment variable overriding the pool width (`1` forces serial).
pub const THREADS_ENV: &str = "COMPASS_THREADS";

#[derive(Debug, Clone, Copy)]
pub struct Runner {
    threads: usize,
}

impl Runner {
    /// A pool of exactly `threads` workers (clamped to ≥ 1).
    pub fn new(threads: usize) -> Runner {
        Runner { threads: threads.max(1) }
    }

    /// The serial engine: `par_map` degenerates to an inline `map`.
    pub fn serial() -> Runner {
        Runner::new(1)
    }

    /// Pool width from the environment: `COMPASS_THREADS` if set to a
    /// positive integer, else all available cores, else serial.
    pub fn from_env() -> Runner {
        let from_var = std::env::var(THREADS_ENV)
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&t| t >= 1);
        let threads = from_var
            .or_else(|| std::thread::available_parallelism().ok().map(|n| n.get()))
            .unwrap_or(1);
        Runner::new(threads)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every item, possibly in parallel, returning results in
    /// input order. `f` gets `(index, &item)`; it must depend only on its
    /// arguments (each experiment run re-seeds from its own config), which
    /// is what makes the output independent of scheduling.
    ///
    /// A panic inside `f` propagates to the caller when the thread scope
    /// joins, matching the serial path's fail-fast behavior.
    pub fn par_map<T, U, F>(&self, items: &[T], f: F) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(usize, &T) -> U + Sync,
    {
        if self.threads == 1 || items.len() <= 1 {
            return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
        }
        // Shared claim cursor (the "steal" point) + indexed write-back
        // slots so completion order never reorders results.
        let cursor = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<U>>> =
            Mutex::new((0..items.len()).map(|_| None).collect());
        let n_threads = self.threads.min(items.len());
        std::thread::scope(|s| {
            for _ in 0..n_threads {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    let out = f(i, &items[i]);
                    results.lock().unwrap()[i] = Some(out);
                });
            }
        });
        results
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("every index claimed exactly once"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_input_order() {
        let items: Vec<usize> = (0..100).collect();
        let serial = Runner::serial().par_map(&items, |i, &x| (i, x * 3));
        let parallel = Runner::new(4).par_map(&items, |i, &x| (i, x * 3));
        assert_eq!(serial, parallel);
        assert_eq!(parallel[17], (17, 51));
    }

    #[test]
    fn par_map_handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(Runner::new(8).par_map(&empty, |_, &x| x).is_empty());
        assert_eq!(Runner::new(8).par_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn threads_clamp_to_one() {
        assert_eq!(Runner::new(0).threads(), 1);
        assert!(Runner::from_env().threads() >= 1);
    }

    #[test]
    fn uneven_work_still_lands_in_slots() {
        // Items late in the list finish first; slots must not shuffle.
        let items: Vec<u64> = (0..32).rev().collect();
        let got = Runner::new(8).par_map(&items, |_, &x| {
            std::thread::sleep(std::time::Duration::from_micros(x * 10));
            x
        });
        assert_eq!(got, items);
    }
}
