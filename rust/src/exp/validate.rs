//! §5.4 — simulator validation against the live system.
//!
//! The paper validated its event-driven simulator against the real 5-worker
//! deployment and saw differences "within 5% of the median numeric values".
//! We replay one identical workload through (a) the discrete-event
//! simulator and (b) the live thread-per-worker coordinator (with real PJRT
//! execution when artifacts are available) and compare median latency and
//! slow-down.

use crate::config::ClusterConfig;
use crate::coordinator::{LiveCluster, LiveConfig};
use crate::util::stats::percentile;
use crate::workload;
use crate::Simulator;
use std::path::PathBuf;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct ValidationResult {
    pub jobs: usize,
    pub sim_median_latency_s: f64,
    pub live_median_latency_s: f64,
    pub sim_median_slowdown: f64,
    pub live_median_slowdown: f64,
    pub pjrt_executions: u64,
}

impl ValidationResult {
    pub fn latency_gap(&self) -> f64 {
        (self.sim_median_latency_s - self.live_median_latency_s).abs()
            / self.live_median_latency_s
    }

    pub fn within_tolerance(&self, tol: f64) -> bool {
        self.latency_gap() <= tol
    }

    pub fn render(&self) -> String {
        format!(
            "validation over {} jobs (5 workers):\n\
             \x20 median latency   sim {:.3} s   live {:.3} s   gap {:.1}%\n\
             \x20 median slowdown  sim {:.3}     live {:.3}\n\
             \x20 live PJRT executions: {}",
            self.jobs,
            self.sim_median_latency_s,
            self.live_median_latency_s,
            100.0 * self.latency_gap(),
            self.sim_median_slowdown,
            self.live_median_slowdown,
            self.pjrt_executions,
        )
    }
}

pub fn run(n_jobs: usize, seed: u64, artifacts: Option<PathBuf>) -> anyhow::Result<ValidationResult> {
    let cfg = ClusterConfig::default().with_seed(seed);
    let jobs = workload::poisson(1.5, n_jobs, &[], seed ^ 0x9e37);

    let sim = Simulator::simulate(cfg.clone(), jobs.clone()).metrics;

    // Live replay, scaled 50x (fast but still far coarser than thread
    // scheduling noise).
    let live_cfg = LiveConfig { time_scale: 50.0, wall_timeout: Duration::from_secs(300) };
    let live = LiveCluster::run(cfg, live_cfg, artifacts, jobs)?;

    let med = |xs: &[f64]| percentile(xs, 50.0);
    let lat = |m: &crate::metrics::MetricsSink| {
        m.jobs.iter().map(|j| j.latency_us() as f64 / 1e6).collect::<Vec<_>>()
    };
    Ok(ValidationResult {
        jobs: n_jobs,
        sim_median_latency_s: med(&lat(&sim)),
        live_median_latency_s: med(&lat(&live.metrics)),
        sim_median_slowdown: sim.median_slowdown(),
        live_median_slowdown: live.metrics.median_slowdown(),
        pjrt_executions: live.pjrt_executions,
    })
}
