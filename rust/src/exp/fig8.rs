//! Figure 8 — sensitivity to SST information staleness (§6.3.2).
//!
//! A high-load scenario swept over the two push intervals independently:
//! x = load (FT) staleness, y = GPU-cache-bitmap staleness. Shape to
//! reproduce: scheduling quality is far more sensitive to *load* staleness
//! (knee around 200 ms) than to *cache* staleness, because model fetches
//! are much rarer events than queue changes.

use super::{run_scenario, Runner, Scale};
use crate::config::SchedulerKind;
use crate::core::MS;

#[derive(Debug, Clone)]
pub struct StalenessGrid {
    /// Push intervals swept on each axis, ms.
    pub intervals_ms: Vec<u64>,
    /// slowdown[load_idx][cache_idx]
    pub slowdown: Vec<Vec<f64>>,
}

impl StalenessGrid {
    pub fn at(&self, load_ms: u64, cache_ms: u64) -> f64 {
        let li = self.intervals_ms.iter().position(|&x| x == load_ms).unwrap();
        let ci = self.intervals_ms.iter().position(|&x| x == cache_ms).unwrap();
        self.slowdown[li][ci]
    }

    /// Mean degradation along one axis with the other held at its best.
    pub fn load_axis_sensitivity(&self) -> f64 {
        let n = self.intervals_ms.len();
        self.slowdown[n - 1][0] / self.slowdown[0][0]
    }

    pub fn cache_axis_sensitivity(&self) -> f64 {
        let n = self.intervals_ms.len();
        self.slowdown[0][n - 1] / self.slowdown[0][0]
    }
}

pub fn compute(scale: Scale) -> StalenessGrid {
    compute_with(&Runner::from_env(), scale)
}

/// The 4×4 staleness grid is 16 independent runs — flatten row-major for
/// the pool, regroup into rows afterwards.
pub fn compute_with(runner: &Runner, scale: Scale) -> StalenessGrid {
    let intervals_ms: Vec<u64> = vec![100, 200, 400, 1000];
    let cells: Vec<(u64, u64)> = intervals_ms
        .iter()
        .flat_map(|&li| intervals_ms.iter().map(move |&ci| (li, ci)))
        .collect();
    let flat = runner.par_map(&cells, |_, &(li, ci)| {
        run_scenario(SchedulerKind::Compass, 2.5, scale, |c| {
            c.push.load_interval_us = li * MS;
            c.push.cache_interval_us = ci * MS;
        })
        .mean_slowdown()
    });
    let slowdown: Vec<Vec<f64>> = flat.chunks(intervals_ms.len()).map(|c| c.to_vec()).collect();
    StalenessGrid { intervals_ms, slowdown }
}

pub fn run(scale: Scale) -> StalenessGrid {
    let g = compute(scale);
    println!("\n=== Figure 8 — staleness sensitivity (mean slow-down) ===");
    println!("rows: load-info push interval; cols: cache-info push interval\n");
    print!("{:>10}", "load\\cache");
    for c in &g.intervals_ms {
        print!("{:>9}", format!("{c}ms"));
    }
    println!();
    for (li, l) in g.intervals_ms.iter().enumerate() {
        print!("{:>10}", format!("{l}ms"));
        for ci in 0..g.intervals_ms.len() {
            print!("{:>9.2}", g.slowdown[li][ci]);
        }
        println!();
    }
    println!(
        "\nload-axis degradation {:.2}x vs cache-axis {:.2}x (paper: load axis dominates)",
        g.load_axis_sensitivity(),
        g.cache_axis_sensitivity()
    );
    g
}
