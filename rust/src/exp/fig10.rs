//! Figure 10 — scalability simulation (§6.5).
//!
//! Poisson workload at 40 req/s over clusters of 5..250 workers, Compass vs
//! Hash. Shape to reproduce: Hash's median slow-down falls toward its floor
//! only around ~100 workers and it keeps *every* worker active; Compass
//! reaches the floor with roughly *half* the workers and leaves the rest
//! completely idle (the paper's headline resource-efficiency claim).

use super::{Runner, Scale};
use crate::config::{ClusterConfig, SchedulerKind};
use crate::util::table;
use crate::workload;
use crate::Simulator;

#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub workers: usize,
    pub median_slowdown: f64,
    pub active_workers: usize,
}

pub struct ScalabilityResult {
    pub compass: Vec<ScalePoint>,
    pub hash: Vec<ScalePoint>,
}

impl ScalabilityResult {
    /// Smallest cluster size whose median slow-down is within 10% of that
    /// scheduler's floor (its minimum across the sweep).
    pub fn floor_reach(points: &[ScalePoint]) -> usize {
        let floor =
            points.iter().map(|p| p.median_slowdown).fold(f64::INFINITY, f64::min);
        points
            .iter()
            .find(|p| p.median_slowdown <= floor * 1.10)
            .map(|p| p.workers)
            .unwrap_or(points.last().unwrap().workers)
    }
}

pub fn compute(scale: Scale, quick: bool) -> ScalabilityResult {
    compute_with(&Runner::from_env(), scale, quick)
}

/// Both schedulers' sweeps share one job stream (borrowed into each run)
/// and flatten into a single work list: the big 250-worker cells and the
/// cheap 5-worker ones self-balance on the stealing cursor.
pub fn compute_with(runner: &Runner, scale: Scale, quick: bool) -> ScalabilityResult {
    let sizes: Vec<usize> =
        if quick { vec![10, 25, 50, 100] } else { vec![5, 10, 25, 50, 75, 100, 150, 200, 250] };
    let n_jobs = if quick { 800 } else { 2000 };
    let jobs = workload::poisson(40.0, n_jobs, &[], scale.seed ^ 0xf16);

    let cells: Vec<(SchedulerKind, usize)> = [SchedulerKind::Compass, SchedulerKind::Hash]
        .iter()
        .flat_map(|&kind| sizes.iter().map(move |&w| (kind, w)))
        .collect();
    let points = runner.par_map(&cells, |_, &(kind, w)| {
        let cfg =
            ClusterConfig::default().with_scheduler(kind).with_workers(w).with_seed(scale.seed);
        let m = Simulator::simulate_ref(&cfg, &jobs).metrics;
        ScalePoint {
            workers: w,
            median_slowdown: m.median_slowdown(),
            active_workers: m.active_workers(),
        }
    });
    let n = sizes.len();
    ScalabilityResult { compass: points[..n].to_vec(), hash: points[n..].to_vec() }
}

pub fn run(scale: Scale, quick: bool) -> ScalabilityResult {
    let r = compute(scale, quick);
    println!("\n=== Figure 10 — scalability at 40 req/s (simulation) ===\n");
    let body: Vec<Vec<String>> = r
        .compass
        .iter()
        .zip(&r.hash)
        .map(|(c, h)| {
            vec![
                format!("{}", c.workers),
                format!("{:.2}", c.median_slowdown),
                format!("{}", c.active_workers),
                format!("{:.2}", h.median_slowdown),
                format!("{}", h.active_workers),
            ]
        })
        .collect();
    print!(
        "{}",
        table::render(
            &["workers", "compass med-slowdown", "compass active", "hash med-slowdown", "hash active"],
            &body
        )
    );
    let cr = ScalabilityResult::floor_reach(&r.compass);
    let hr = ScalabilityResult::floor_reach(&r.hash);
    println!(
        "\ncompass reaches its slow-down floor at {cr} workers; hash at {hr} \
         (paper: Navigator needs ~half the workers Hash does)"
    );
    r
}
