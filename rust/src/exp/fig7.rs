//! Figure 7 — ablation analysis (§6.3.1): selectively disable each Compass
//! feature and measure the damage at low/medium/high request rates.
//!
//! Shape to reproduce: dynamic adjustment and model locality each matter a
//! lot (paper: 8× degradation without locality, hit rate 99% → ~90%);
//! queue-lookahead eviction beats FIFO at high rate but is a wash at low
//! rate.

use super::{run_scenario, Runner, Scale};
use crate::config::SchedulerKind;
use crate::gpu::EvictionPolicy;
use crate::util::table;

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub variant: &'static str,
    /// Mean slow-down at each swept rate.
    pub means: Vec<f64>,
    /// Cache hit rate (%) at the highest rate.
    pub hit_rate_pct: f64,
}

pub const RATES: [f64; 3] = [0.5, 1.5, 2.5];

pub fn compute(scale: Scale) -> Vec<AblationRow> {
    compute_with(&Runner::from_env(), scale)
}

/// Flatten `variant × rate` into independent cells for the pool, then
/// regroup per variant. The reported hit rate is the last-rate cell's, the
/// same cell the serial loop left in its accumulator.
pub fn compute_with(runner: &Runner, scale: Scale) -> Vec<AblationRow> {
    type Mutator = fn(&mut crate::config::ClusterConfig);
    let variants: Vec<(&'static str, Mutator)> = vec![
        ("compass-full", |_| {}),
        ("no-dynamic-adjust", |c| c.compass.dynamic_adjust = false),
        ("fifo-eviction", |c| c.eviction = EvictionPolicy::Fifo),
        ("no-model-locality", |c| c.compass.model_locality = false),
    ];
    let cells: Vec<(Mutator, f64)> = variants
        .iter()
        .flat_map(|&(_, mutate)| RATES.iter().map(move |&r| (mutate, r)))
        .collect();
    let flat = runner.par_map(&cells, |_, &(mutate, r)| {
        let m = run_scenario(SchedulerKind::Compass, r, scale, mutate);
        (m.mean_slowdown(), m.cache_hit_rate())
    });
    variants
        .iter()
        .zip(flat.chunks(RATES.len()))
        .map(|(&(name, _), chunk)| AblationRow {
            variant: name,
            means: chunk.iter().map(|&(slow, _)| slow).collect(),
            hit_rate_pct: chunk.last().unwrap().1,
        })
        .collect()
}

pub fn run(scale: Scale) -> Vec<AblationRow> {
    let rows = compute(scale);
    println!("\n=== Figure 7 — ablation analysis (mean slow-down factor) ===\n");
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut v = vec![r.variant.to_string()];
            v.extend(r.means.iter().map(|m| format!("{m:.2}")));
            v.push(format!("{:.1}", r.hit_rate_pct));
            v
        })
        .collect();
    let mut headers: Vec<String> = vec!["variant".into()];
    headers.extend(RATES.iter().map(|r| format!("{r} req/s")));
    headers.push("hit rate % @hi".into());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    print!("{}", table::render(&hdr, &body));
    rows
}
