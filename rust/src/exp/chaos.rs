//! Chaos sweep — scheduler robustness under injected worker crashes.
//!
//! Runs the Compass scheduler on the standard 4-pipeline mix while sweeping
//! the per-worker crash probability (DESIGN.md §9), reporting what the
//! recovery machinery delivers at each point: completion rate, p99 latency
//! of the jobs that did finish, and the raw fault counters (workers failed,
//! tasks re-placed, degraded jobs). Expected shape: completion stays at
//! 100% while any worker survives — crashes cost latency (re-placed tails)
//! and degraded outcomes, not results — and only collapses when the crash
//! rate kills the whole cluster.
//!
//! `run` also writes `BENCH_chaos.json` so CI can gate on the two
//! structural invariants (100% completion at rate 0; nonzero re-placement
//! activity once crashes are injected) and archive the curve.

use super::{Runner, Scale};
use crate::config::{ClusterConfig, SchedulerKind};
use crate::metrics::MetricsSink;
use crate::util::json::Json;
use crate::util::stats::percentile;
use crate::util::table;
use crate::workload;
use crate::Simulator;
use std::collections::BTreeMap;

/// Request rate for the sweep: the paper's Fig. 6b high-load operating
/// point, so crashes land on a cluster with real queues to orphan.
const SWEEP_RATE: f64 = 2.0;

/// Swept per-worker crash probabilities. The top cell expects most of the
/// five default workers dead before the run ends.
const CRASH_RATES: [f64; 4] = [0.0, 0.2, 0.4, 0.8];

/// One sweep cell, in `CRASH_RATES` order.
pub struct ChaosCell {
    pub crash_rate: f64,
    pub completion_rate: f64,
    pub p99_latency_s: f64,
    pub workers_failed: u64,
    pub tasks_re_placed: u64,
    pub degraded_jobs: usize,
    pub jobs_failed: u64,
}

pub struct ChaosSweepResult {
    pub cells: Vec<ChaosCell>,
}

impl ChaosSweepResult {
    pub fn cell_at(&self, crash_rate: f64) -> &ChaosCell {
        self.cells
            .iter()
            .find(|c| c.crash_rate == crash_rate)
            .expect("swept crash rate")
    }

    /// Re-placements summed over every crash-injecting cell — what the CI
    /// gate checks is nonzero.
    pub fn total_re_placed(&self) -> u64 {
        self.cells.iter().filter(|c| c.crash_rate > 0.0).map(|c| c.tasks_re_placed).sum()
    }

    fn to_json(&self) -> Json {
        let rows = self
            .cells
            .iter()
            .map(|c| {
                let mut o = BTreeMap::new();
                o.insert("crash_rate".to_string(), Json::Num(c.crash_rate));
                o.insert("completion_rate".to_string(), Json::Num(c.completion_rate));
                o.insert("p99_latency_s".to_string(), Json::Num(c.p99_latency_s));
                o.insert("workers_failed".to_string(), Json::Num(c.workers_failed as f64));
                o.insert("tasks_re_placed".to_string(), Json::Num(c.tasks_re_placed as f64));
                o.insert("degraded_jobs".to_string(), Json::Num(c.degraded_jobs as f64));
                o.insert("jobs_failed".to_string(), Json::Num(c.jobs_failed as f64));
                Json::Obj(o)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("chaos".to_string(), Json::Arr(rows));
        Json::Obj(top)
    }
}

fn scenario(crash_rate: f64, scale: Scale) -> MetricsSink {
    let mut cfg =
        ClusterConfig::default().with_scheduler(SchedulerKind::Compass).with_seed(scale.seed);
    cfg.fault.crash_rate = crash_rate;
    // Identical request stream in every cell: only the fault plan varies.
    let jobs = workload::poisson(SWEEP_RATE, scale.jobs, &[], scale.seed ^ 0x9e37_79b9);
    Simulator::simulate(cfg, jobs).metrics
}

/// Every cell is an independent run: fan them across the runner's pool.
/// Results come back in sweep order, so output is identical at any thread
/// count (the experiments-smoke serial-vs-parallel diff covers this).
pub fn compute_sweep(runner: &Runner, scale: Scale) -> ChaosSweepResult {
    let rates: Vec<f64> = CRASH_RATES.to_vec();
    let cells = runner.par_map(&rates, |_, &crash_rate| {
        let m = scenario(crash_rate, scale);
        let lat = m.latencies_s();
        ChaosCell {
            crash_rate,
            completion_rate: m.completion_rate(),
            p99_latency_s: if lat.is_empty() { 0.0 } else { percentile(&lat, 99.0) },
            workers_failed: m.faults.workers_failed,
            tasks_re_placed: m.faults.tasks_re_placed,
            degraded_jobs: m.degraded_jobs(),
            jobs_failed: m.faults.jobs_failed,
        }
    });
    ChaosSweepResult { cells }
}

pub fn run(scale: Scale) -> ChaosSweepResult {
    let result = compute_sweep(&Runner::from_env(), scale);

    println!("\n=== Chaos sweep — completion/p99 vs crash rate, {SWEEP_RATE} req/s ===\n");
    let mut rows = Vec::new();
    for c in &result.cells {
        rows.push(vec![
            format!("{:.1}", c.crash_rate),
            format!("{:.1}", c.completion_rate),
            format!("{:.3}", c.p99_latency_s),
            format!("{}", c.workers_failed),
            format!("{}", c.tasks_re_placed),
            format!("{}", c.degraded_jobs),
            format!("{}", c.jobs_failed),
        ]);
    }
    print!(
        "{}",
        table::render(
            &[
                "crash_rate",
                "completion %",
                "p99 latency s",
                "workers failed",
                "re-placed",
                "degraded",
                "jobs failed"
            ],
            &rows
        )
    );
    let path = "BENCH_chaos.json";
    match std::fs::write(path, format!("{}\n", result.to_json())) {
        Ok(()) => println!("chaos report written to {path}"),
        Err(e) => eprintln!("chaos report not written to {path}: {e}"),
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_sweep_is_deterministic_and_recovers() {
        let scale = Scale { jobs: 60, seed: 17 };
        let serial = compute_sweep(&Runner::serial(), scale);
        let parallel = compute_sweep(&Runner::from_env(), scale);
        assert_eq!(serial.cells.len(), CRASH_RATES.len());
        for (a, b) in serial.cells.iter().zip(parallel.cells.iter()) {
            assert_eq!(a.crash_rate.to_bits(), b.crash_rate.to_bits());
            assert_eq!(a.completion_rate.to_bits(), b.completion_rate.to_bits());
            assert_eq!(a.p99_latency_s.to_bits(), b.p99_latency_s.to_bits());
            assert_eq!(a.tasks_re_placed, b.tasks_re_placed);
            assert_eq!(a.workers_failed, b.workers_failed);
        }
        let baseline = serial.cell_at(0.0);
        assert_eq!(baseline.completion_rate, 100.0, "no crashes, no losses");
        assert_eq!(baseline.workers_failed, 0);
        assert_eq!(baseline.tasks_re_placed, 0);
        assert!(
            serial.total_re_placed() > 0,
            "crash injection must exercise recovery re-placement"
        );
    }
}
