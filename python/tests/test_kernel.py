"""Kernel vs oracle allclose — the CORE L1 correctness signal.

Fixed-shape unit tests plus hypothesis sweeps over shapes/dtypes. Every
kernel runs interpret=True (see kernels/__init__.py), so these pin exactly
what the AOT artifacts will compute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention, layernorm, tiled_matmul
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

TOL = dict(rtol=2e-4, atol=2e-4)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- attention

class TestAttention:
    def test_basic(self):
        q, k, v = (_rand(i, (2, 32, 16)) for i in range(3))
        np.testing.assert_allclose(
            flash_attention(q, k, v), ref.attention_ref(q, k, v), **TOL)

    def test_single_head(self):
        q, k, v = (_rand(i, (1, 16, 8)) for i in range(3))
        np.testing.assert_allclose(
            flash_attention(q, k, v, block_q=8, block_k=8),
            ref.attention_ref(q, k, v), **TOL)

    def test_block_shape_invariance(self):
        """Result must not depend on the tiling decomposition."""
        q, k, v = (_rand(i, (2, 64, 16)) for i in range(3))
        a8 = flash_attention(q, k, v, block_q=8, block_k=8)
        a16 = flash_attention(q, k, v, block_q=16, block_k=16)
        a_mixed = flash_attention(q, k, v, block_q=16, block_k=8)
        np.testing.assert_allclose(a8, a16, **TOL)
        np.testing.assert_allclose(a8, a_mixed, **TOL)

    def test_softmax_rows_sum_via_uniform_v(self):
        """With v = all-ones, output must be exactly ones (softmax sums to 1)."""
        q, k = _rand(0, (2, 32, 16)), _rand(1, (2, 32, 16))
        v = jnp.ones((2, 32, 16), jnp.float32)
        np.testing.assert_allclose(
            flash_attention(q, k, v), jnp.ones_like(v), rtol=1e-5, atol=1e-5)

    def test_large_logits_stable(self):
        """Online softmax must not overflow with large score magnitudes."""
        q = _rand(0, (1, 32, 16)) * 100.0
        k = _rand(1, (1, 32, 16)) * 100.0
        v = _rand(2, (1, 32, 16))
        out = flash_attention(q, k, v)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(out, ref.attention_ref(q, k, v),
                                   rtol=1e-3, atol=1e-3)

    def test_rejects_indivisible_seq(self):
        q = k = v = jnp.zeros((1, 24, 8))
        with pytest.raises(ValueError):
            flash_attention(q, k, v, block_q=16, block_k=16)

    @settings(max_examples=20, deadline=None)
    @given(
        bh=st.integers(1, 4),
        nq=st.sampled_from([1, 2, 4]),
        nk=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([4, 8, 16, 32]),
        blk=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, bh, nq, nk, d, blk, seed):
        s = blk * max(nq, nk)
        q = _rand(seed, (bh, s, d))
        k = _rand(seed + 1, (bh, s, d))
        v = _rand(seed + 2, (bh, s, d))
        np.testing.assert_allclose(
            flash_attention(q, k, v, block_q=blk, block_k=blk),
            ref.attention_ref(q, k, v), **TOL)


# ------------------------------------------------------------------ matmul

class TestMatmul:
    def test_basic(self):
        x, w = _rand(0, (32, 48)), _rand(1, (48, 64))
        np.testing.assert_allclose(
            tiled_matmul(x, w), ref.matmul_ref(x, w), **TOL)

    def test_identity(self):
        x = _rand(0, (16, 16))
        np.testing.assert_allclose(
            tiled_matmul(x, jnp.eye(16)), x, rtol=1e-6, atol=1e-6)

    def test_block_invariance(self):
        x, w = _rand(0, (32, 32)), _rand(1, (32, 32))
        a = tiled_matmul(x, w, block_m=8, block_n=8, block_k=8)
        b = tiled_matmul(x, w, block_m=16, block_n=16, block_k=16)
        np.testing.assert_allclose(a, b, **TOL)

    def test_rejects_indivisible(self):
        with pytest.raises(ValueError):
            tiled_matmul(jnp.zeros((30, 32)), jnp.zeros((32, 32)))

    def test_shape_mismatch_asserts(self):
        with pytest.raises(AssertionError):
            tiled_matmul(jnp.zeros((16, 16)), jnp.zeros((32, 16)))

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 32, 48]),
        k=st.sampled_from([8, 16, 32, 48]),
        n=st.sampled_from([8, 16, 32, 48]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, m, k, n, seed):
        x, w = _rand(seed, (m, k)), _rand(seed + 1, (k, n))
        np.testing.assert_allclose(
            tiled_matmul(x, w, block_m=8, block_n=8, block_k=8),
            ref.matmul_ref(x, w), **TOL)


# ---------------------------------------------------------------- layernorm

class TestLayernorm:
    def test_basic(self):
        x = _rand(0, (32, 48))
        g, b = _rand(1, (48,)), _rand(2, (48,))
        np.testing.assert_allclose(
            layernorm(x, g, b), ref.layernorm_ref(x, g, b), **TOL)

    def test_unit_gamma_zero_beta_stats(self):
        """Rows of the normalized output have mean 0 and var 1."""
        x = _rand(0, (16, 64)) * 5.0 + 3.0
        y = np.asarray(layernorm(x, jnp.ones(64), jnp.zeros(64)))
        np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-5)
        np.testing.assert_allclose(y.var(-1), 1.0, atol=1e-3)

    def test_constant_rows(self):
        """A constant row normalizes to beta (variance ~ 0 handled by eps)."""
        x = jnp.full((16, 32), 7.0)
        b = _rand(1, (32,))
        y = layernorm(x, jnp.ones(32), b)
        np.testing.assert_allclose(y, jnp.broadcast_to(b, (16, 32)),
                                   rtol=1e-3, atol=1e-3)

    def test_rejects_indivisible_rows(self):
        with pytest.raises(ValueError):
            layernorm(jnp.zeros((30, 32)), jnp.ones(32), jnp.zeros(32),
                      block_rows=16)

    @settings(max_examples=15, deadline=None)
    @given(
        t=st.sampled_from([8, 16, 32]),
        d=st.sampled_from([8, 16, 32, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shapes(self, t, d, seed):
        x = _rand(seed, (t, d))
        g, b = _rand(seed + 1, (d,)), _rand(seed + 2, (d,))
        np.testing.assert_allclose(
            layernorm(x, g, b, block_rows=8),
            ref.layernorm_ref(x, g, b), **TOL)
