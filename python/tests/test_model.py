"""L2 model tests: pallas path vs pure-jnp reference path, shapes, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (MODEL_SPECS, build_model_fn, init_params,
                           reference_forward)
from compile.aot import smoke_input

ALL_MODELS = sorted(MODEL_SPECS)


class TestSpecs:
    def test_eight_models(self):
        assert len(MODEL_SPECS) == 8

    def test_model_ids_unique_and_dense(self):
        ids = sorted(s.model_id for s in MODEL_SPECS.values())
        assert ids == list(range(8))

    @pytest.mark.parametrize("name", ALL_MODELS)
    def test_head_dim_divides(self, name):
        spec = MODEL_SPECS[name]
        assert spec.d_model % spec.n_heads == 0

    @pytest.mark.parametrize("name", ALL_MODELS)
    def test_param_count_positive(self, name):
        assert MODEL_SPECS[name].param_count() > 0


class TestForward:
    @pytest.mark.parametrize("name", ALL_MODELS)
    def test_pallas_matches_reference(self, name):
        spec = MODEL_SPECS[name]
        fn, _ = build_model_fn(name, use_pallas=True)
        x = smoke_input(spec)
        (y,) = jax.jit(fn)(x)
        yr = reference_forward(name, x)
        np.testing.assert_allclose(y, yr, rtol=5e-4, atol=5e-4)

    @pytest.mark.parametrize("name", ALL_MODELS)
    def test_output_shape(self, name):
        spec = MODEL_SPECS[name]
        fn, ex = build_model_fn(name)
        assert ex.shape == (spec.seq_len, spec.d_model)
        (y,) = jax.jit(fn)(smoke_input(spec))
        assert y.shape == (spec.seq_len, spec.d_model)

    def test_weights_deterministic(self):
        p1 = init_params(MODEL_SPECS["opt"])
        p2 = init_params(MODEL_SPECS["opt"])
        np.testing.assert_array_equal(p1["layers"][0]["wq"],
                                      p2["layers"][0]["wq"])

    def test_distinct_models_distinct_weights(self):
        po = init_params(MODEL_SPECS["opt"])
        pb = init_params(MODEL_SPECS["bart"])
        assert not np.array_equal(po["layers"][0]["wq"], pb["layers"][0]["wq"])

    def test_output_finite(self):
        for name in ALL_MODELS:
            spec = MODEL_SPECS[name]
            fn, _ = build_model_fn(name)
            (y,) = jax.jit(fn)(smoke_input(spec) * 10.0)
            assert np.isfinite(np.asarray(y)).all(), name
