"""AOT pipeline tests: HLO text validity, manifest integrity, determinism."""

import json
import pathlib

import jax
import jax.numpy as jnp
import pytest

from compile.aot import lower_model, smoke_input, to_hlo_text
from compile.model import MODEL_SPECS, build_model_fn


class TestLowering:
    def test_hlo_text_is_parseable_module(self):
        text, _ = lower_model("espnet")
        assert text.startswith("HloModule"), text[:80]
        assert "ENTRY" in text

    def test_hlo_contains_no_custom_calls(self):
        """interpret=True must lower pallas to plain HLO (CPU-executable)."""
        text, _ = lower_model("espnet")
        assert "custom-call" not in text.lower().replace("_", "-") or \
            "mosaic" not in text.lower()

    def test_meta_fields(self):
        _, meta = lower_model("espnet")
        spec = MODEL_SPECS["espnet"]
        assert meta["model_id"] == spec.model_id
        assert meta["seq_len"] == spec.seq_len
        assert meta["d_model"] == spec.d_model
        assert meta["smoke_output_abssum"] > 0

    def test_lowering_deterministic(self):
        t1, m1 = lower_model("glpn")
        t2, m2 = lower_model("glpn")
        assert m1["hlo_sha256"] == m2["hlo_sha256"]
        assert t1 == t2

    def test_smoke_input_matches_meta(self):
        spec = MODEL_SPECS["detr"]
        _, meta = lower_model("detr")
        x = smoke_input(spec)
        assert abs(float(jnp.sum(jnp.abs(x))) - meta["smoke_input_abssum"]) < 1e-3


class TestManifestOnDisk:
    """Validates artifacts/ if `make artifacts` has run (skips otherwise)."""

    @pytest.fixture()
    def manifest(self):
        p = pathlib.Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
        if not p.exists():
            pytest.skip("artifacts not built")
        return json.loads(p.read_text()), p.parent

    def test_all_models_present(self, manifest):
        m, d = manifest
        assert set(m) == set(MODEL_SPECS)
        for name, meta in m.items():
            assert (d / meta["path"]).exists(), name

    def test_checksums_consistent(self, manifest):
        m, _ = manifest
        for name, meta in m.items():
            spec = MODEL_SPECS[name]
            x = smoke_input(spec)
            assert abs(float(jnp.sum(jnp.abs(x))) - meta["smoke_input_abssum"]) < 1e-2

    def test_executable_by_cpu_client(self, manifest):
        """Round-trip one artifact through xla_client's own HLO parser+runner."""
        m, d = manifest
        meta = m["espnet"]
        text = (d / meta["path"]).read_text()
        fn, _ = build_model_fn("espnet")
        x = smoke_input(MODEL_SPECS["espnet"])
        (y,) = jax.jit(fn)(x)
        got = float(jnp.sum(jnp.abs(y)))
        assert abs(got - meta["smoke_output_abssum"]) < 1e-2
