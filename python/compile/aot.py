"""AOT pipeline: lower every model variant to HLO *text* + write a manifest.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
    <name>.hlo.txt   one per model in MODEL_SPECS
    manifest.json    {name: {model_id, seq_len, d_model, path, checksum_input,
                             checksum_output}}

The checksums are abs-sums of a deterministic smoke input/output pair;
the rust runtime re-runs the same pair at load time as an end-to-end
numerical handshake between the python and rust halves.

Usage:  cd python && python -m compile.aot [--out-dir ../artifacts] [--only NAME]
"""

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import MODEL_SPECS, build_model_fn


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange).

    CRITICAL: the default HLO printer ELIDES large constants ("...") — the
    baked model weights would silently become garbage on the rust side (the
    final layernorm masks the damage, so only a numerical handshake catches
    it). Print with ``print_large_constants=True``.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # xla_extension 0.5.1's parser predates newer metadata attributes
    # (source_end_line etc.) — strip metadata entirely.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "..." not in text, "HLO printer still eliding constants"
    return text


def smoke_input(spec) -> jax.Array:
    """Deterministic smoke-test activation for the rust handshake."""
    s, d = spec.seq_len, spec.d_model
    i = jnp.arange(s * d, dtype=jnp.float32).reshape(s, d)
    return jnp.sin(i * 0.01)


def lower_model(name: str) -> tuple[str, dict]:
    spec = MODEL_SPECS[name]
    fn, example = build_model_fn(name, use_pallas=True)
    lowered = jax.jit(fn).lower(example)
    text = to_hlo_text(lowered)

    x = smoke_input(spec)
    (y,) = jax.jit(fn)(x)
    meta = {
        "model_id": spec.model_id,
        "seq_len": spec.seq_len,
        "d_model": spec.d_model,
        "n_layers": spec.n_layers,
        "n_heads": spec.n_heads,
        "path": f"{name}.hlo.txt",
        "smoke_input_abssum": float(jnp.sum(jnp.abs(x))),
        "smoke_output_abssum": float(jnp.sum(jnp.abs(y))),
        "hlo_sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-file mode: also write the first model here")
    ap.add_argument("--only", default=None, help="lower a single model")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    names = [args.only] if args.only else list(MODEL_SPECS)
    manifest = {}
    for name in names:
        text, meta = lower_model(name)
        (out_dir / meta["path"]).write_text(text)
        manifest[name] = meta
        print(f"lowered {name:10s} -> {meta['path']} "
              f"({len(text) / 1024:.0f} KiB, out_abssum={meta['smoke_output_abssum']:.4f})")

    mpath = out_dir / "manifest.json"
    existing = json.loads(mpath.read_text()) if mpath.exists() else {}
    existing.update(manifest)
    mpath.write_text(json.dumps(existing, indent=2, sort_keys=True))
    print(f"wrote {mpath} ({len(existing)} models)")

    if args.out:
        first = names[0]
        text, _ = lower_model(first)
        pathlib.Path(args.out).write_text(text)


if __name__ == "__main__":
    main()
