"""L2: the JAX compute graph executed by every ML vertex in a Compass DFG.

Each of the paper's eight models (OPT-1.3b, Marian, mT5, ViT-GPT2, ESPnet,
BART, DETR, GLPN-depth) is represented by a *tiny* pre-LN transformer encoder
instantiated at a model-specific size (the scheduler only ever consumes the
*profiled* GB-scale sizes and runtimes attached in the rust profile
repository — see DESIGN.md §3 substitutions — while the compute path runs
this real network through PJRT).

The forward pass calls the L1 Pallas kernels (``flash_attention``,
``tiled_matmul``, ``layernorm``); setting ``use_pallas=False`` swaps in the
pure-jnp oracles from ``kernels.ref`` so the full model has a reference path
too (used by pytest to pin model-level numerics).

Weights are generated deterministically from the model name so that the AOT
artifacts embed them as HLO constants: the rust runtime then only feeds
activations.
"""

import dataclasses
import hashlib

import jax
import jax.numpy as jnp

from .kernels import flash_attention, layernorm, tiled_matmul
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Architecture of one tiny-transformer model variant.

    ``model_id`` is the Compass model-table id (bit position in the SST cache
    bitmap); it must match ``rust/src/dfg/models.rs``.
    """

    name: str
    model_id: int
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    seq_len: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        per_layer = 4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff
        norms = self.n_layers * 4 * self.d_model + 2 * self.d_model
        return self.n_layers * per_layer + norms


# The eight models of Figure 1, ids matching the rust model table
# (rust/src/dfg/models.rs). Sizes/seq vary so artifacts genuinely differ.
MODEL_SPECS = {
    "opt": ModelSpec("opt", 0, d_model=64, n_heads=4, n_layers=3, d_ff=128, seq_len=32),
    "marian": ModelSpec("marian", 1, d_model=48, n_heads=3, n_layers=2, d_ff=96, seq_len=32),
    "mt5": ModelSpec("mt5", 2, d_model=64, n_heads=4, n_layers=2, d_ff=128, seq_len=32),
    "vit_gpt2": ModelSpec("vit_gpt2", 3, d_model=48, n_heads=3, n_layers=2, d_ff=96, seq_len=16),
    "espnet": ModelSpec("espnet", 4, d_model=32, n_heads=2, n_layers=2, d_ff=64, seq_len=16),
    "bart": ModelSpec("bart", 5, d_model=64, n_heads=4, n_layers=2, d_ff=128, seq_len=32),
    "detr": ModelSpec("detr", 6, d_model=48, n_heads=3, n_layers=2, d_ff=96, seq_len=16),
    "glpn": ModelSpec("glpn", 7, d_model=32, n_heads=2, n_layers=3, d_ff=64, seq_len=16),
}


def _seed_for(name: str) -> int:
    """Stable cross-run seed (``hash()`` is salted per-process; sha256 isn't)."""
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "big")


def init_params(spec: ModelSpec) -> dict:
    """Deterministic weights keyed by model name (baked into the artifact)."""
    key = jax.random.PRNGKey(_seed_for(spec.name))
    keys = jax.random.split(key, spec.n_layers * 6)
    d, f = spec.d_model, spec.d_ff
    scale = 1.0 / (d ** 0.5)
    layers = []
    for i in range(spec.n_layers):
        k = keys[i * 6:(i + 1) * 6]
        layers.append({
            "wq": jax.random.normal(k[0], (d, d), jnp.float32) * scale,
            "wk": jax.random.normal(k[1], (d, d), jnp.float32) * scale,
            "wv": jax.random.normal(k[2], (d, d), jnp.float32) * scale,
            "wo": jax.random.normal(k[3], (d, d), jnp.float32) * scale,
            "w1": jax.random.normal(k[4], (d, f), jnp.float32) * scale,
            "w2": jax.random.normal(k[5], (f, d), jnp.float32) * (1.0 / f ** 0.5),
            "ln1_g": jnp.ones((d,), jnp.float32),
            "ln1_b": jnp.zeros((d,), jnp.float32),
            "ln2_g": jnp.ones((d,), jnp.float32),
            "ln2_b": jnp.zeros((d,), jnp.float32),
        })
    return {
        "layers": layers,
        "lnf_g": jnp.ones((d,), jnp.float32),
        "lnf_b": jnp.zeros((d,), jnp.float32),
    }


def _blk(n: int) -> int:
    """Largest of {16, 8, 4} dividing n (all model dims are multiples of 4)."""
    for b in (16, 8, 4):
        if n % b == 0:
            return b
    raise ValueError(f"dim {n} not a multiple of 4")


def _mm(x, w, use_pallas):
    if use_pallas:
        return tiled_matmul(x, w, block_m=_blk(x.shape[0]),
                            block_k=_blk(x.shape[1]), block_n=_blk(w.shape[1]))
    return kref.matmul_ref(x, w)


def _ln(x, g, b, use_pallas):
    if use_pallas:
        return layernorm(x, g, b, block_rows=_blk(x.shape[0]))
    return kref.layernorm_ref(x, g, b)


def _attn(q, k, v, use_pallas):
    if use_pallas:
        blk = _blk(q.shape[1])
        return flash_attention(q, k, v, block_q=blk, block_k=blk)
    return kref.attention_ref(q, k, v)


def _block(spec: ModelSpec, p: dict, x: jax.Array, use_pallas: bool) -> jax.Array:
    """One pre-LN transformer block over [S, D] activations."""
    s, d = x.shape
    h, hd = spec.n_heads, spec.head_dim

    y = _ln(x, p["ln1_g"], p["ln1_b"], use_pallas)
    q = _mm(y, p["wq"], use_pallas).reshape(s, h, hd).transpose(1, 0, 2)
    k = _mm(y, p["wk"], use_pallas).reshape(s, h, hd).transpose(1, 0, 2)
    v = _mm(y, p["wv"], use_pallas).reshape(s, h, hd).transpose(1, 0, 2)
    o = _attn(q, k, v, use_pallas)                       # [H, S, hd]
    o = o.transpose(1, 0, 2).reshape(s, d)
    x = x + _mm(o, p["wo"], use_pallas)

    y = _ln(x, p["ln2_g"], p["ln2_b"], use_pallas)
    y = jax.nn.gelu(_mm(y, p["w1"], use_pallas))
    x = x + _mm(y, p["w2"], use_pallas)
    return x


def forward(spec: ModelSpec, params: dict, x: jax.Array,
            use_pallas: bool = True) -> jax.Array:
    """Full forward pass: [S, D] -> [S, D]."""
    for p in params["layers"]:
        x = _block(spec, p, x, use_pallas)
    return _ln(x, params["lnf_g"], params["lnf_b"], use_pallas)


def build_model_fn(name: str, use_pallas: bool = True):
    """Return ``(fn, example_input)`` for AOT lowering.

    ``fn`` closes over deterministic weights (they become HLO constants) and
    returns a 1-tuple — the rust loader unwraps with ``to_tuple1``.
    """
    spec = MODEL_SPECS[name]
    params = init_params(spec)

    def fn(x):
        return (forward(spec, params, x, use_pallas=use_pallas),)

    example = jax.ShapeDtypeStruct((spec.seq_len, spec.d_model), jnp.float32)
    return fn, example


def reference_forward(name: str, x: jax.Array) -> jax.Array:
    """Pure-jnp forward (oracle path) for model-level tests."""
    spec = MODEL_SPECS[name]
    params = init_params(spec)
    return forward(spec, params, x, use_pallas=False)
