"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a reference implementation here written with
plain jax.numpy ops only. ``python/tests`` asserts allclose between each
kernel (interpret=True) and its oracle across a hypothesis-driven sweep of
shapes and dtypes.
"""

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Scaled dot-product attention, no masking.

    Shapes: q,k,v: [BH, S, D] (batch*heads folded into the leading dim).
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    s = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain 2-D matmul oracle: [M, K] @ [K, N] -> [M, N]."""
    return jnp.dot(x, w, preferred_element_type=x.dtype)


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
    """Row-wise layer normalization oracle over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def ffn_ref(x: jax.Array, w1: jax.Array, w2: jax.Array) -> jax.Array:
    """Transformer feed-forward oracle: GELU MLP. x: [T, D], w1: [D, F], w2: [F, D]."""
    return jax.nn.gelu(x @ w1) @ w2
