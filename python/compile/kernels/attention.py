"""L1 Pallas kernel: tiled flash-attention with online softmax.

This is the compute hot-spot of every ML vertex in the Compass pipelines
(the per-model transformer forward pass in ``model.py`` calls it for each
attention layer).

TPU adaptation of the flash-attention idea (paper targets NVIDIA T4s):
instead of a CUDA threadblock schedule over shared memory, the HBM->VMEM
schedule is expressed through ``BlockSpec``s — one Q block is resident in
VMEM per grid step while K/V are streamed through it block-by-block inside
the kernel with an online-softmax accumulator, so VMEM footprint is
O(block_q * d + 2 * block_k * d) regardless of sequence length. The inner
``q @ k.T`` / ``p @ v`` contractions are plain dots that map onto the MXU.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO ops. Correctness is
pinned to ``ref.attention_ref`` by the pytest/hypothesis suite.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                            sm_scale: float):
    """One grid step: one (batch*head, q-block) tile.

    Ref block shapes: q_ref [1, block_q, d]; k_ref/v_ref [1, S, d];
    o_ref [1, block_q, d]. K/V are streamed in ``block_k`` slices with the
    classic online-softmax (m, l, acc) carry.
    """
    q = q_ref[0].astype(jnp.float32) * sm_scale
    block_q, d = q.shape
    seq_len = k_ref.shape[1]
    num_kb = seq_len // block_k

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # [block_q, block_k]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_cur, l_cur, acc

    m0 = jnp.full((block_q,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    block_q: int = 16, block_k: int = 16) -> jax.Array:
    """Tiled attention over [BH, S, D] operands.

    ``block_q``/``block_k`` must divide S (callers pad if not; the model
    layer always uses power-of-two sequence lengths).
    """
    bh, seq_len, d = q.shape
    if seq_len % block_q or seq_len % block_k:
        raise ValueError(
            f"seq_len {seq_len} not divisible by blocks ({block_q},{block_k})")
    sm_scale = 1.0 / (d ** 0.5)
    grid = (bh, seq_len // block_q)
    kernel = functools.partial(
        _flash_attention_kernel, block_k=block_k, sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq_len, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq_len, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(q, k, v)
