"""L1 Pallas kernel: row-blocked layer normalization.

Each grid step normalizes a block of rows held in VMEM. Mean/variance are
computed in f32 regardless of input dtype (matching the oracle's numerics).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32)
                  + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def layernorm(x: jax.Array, gamma: jax.Array, beta: jax.Array, *,
              block_rows: int = 16, eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis of [T, D]; gamma/beta are [D]."""
    t, d = x.shape
    if t % block_rows:
        raise ValueError(f"rows {t} not divisible by block_rows {block_rows}")
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(t // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, gamma, beta)
