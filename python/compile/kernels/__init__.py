"""L1 Pallas kernels for the Compass model compute hot-spots.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); ``ref.py`` holds the pure-jnp oracles used by the test suite.
"""

from .attention import flash_attention
from .layernorm import layernorm
from .matmul import tiled_matmul

__all__ = ["flash_attention", "layernorm", "tiled_matmul"]
