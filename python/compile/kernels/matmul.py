"""L1 Pallas kernel: tiled matmul (the FFN hot path).

Blocked over (M, N, K) with a VMEM accumulator; the K axis is the innermost
grid dimension so the accumulator tile stays resident while K blocks stream
through VMEM. Block sizes default to MXU-friendly multiples; the model layer
picks blocks that divide its (tiny) dims.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, num_k: int):
    """Grid step (m, n, k): accumulate x[m,k] @ w[k,n] into acc, flush at k end."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kb == num_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def tiled_matmul(x: jax.Array, w: jax.Array, *, block_m: int = 16,
                 block_n: int = 16, block_k: int = 16) -> jax.Array:
    """[M, K] @ [K, N] -> [M, N] with VMEM-blocked accumulation."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(f"dims {(m, k, n)} not divisible by blocks "
                         f"({block_m},{block_k},{block_n})")
    num_k = k // block_k
    grid = (m // block_m, n // block_n, num_k)
    kernel = functools.partial(_matmul_kernel, num_k=num_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kb: (kb, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=True,
    )(x, w)
